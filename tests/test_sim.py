"""repro.sim deterministic-simulation harness: determinism, fault plans
under guards, oracle teeth (guard ablations), replayable failure seeds,
and the sim building blocks (clock / scheduler / trace / model store)."""

import random

import pytest

from repro.core.distributed_cache import DistributedPlanCache, ShardUnavailable
from repro.envs.workloads import SIM_SCENARIOS, sim_traffic
from repro.sim import (
    ABLATION_OF,
    FAULT_PLANS,
    ModelStore,
    SimConfig,
    StepScheduler,
    TraceRecorder,
    VirtualClock,
    make_value,
    run_sim,
    value_torn,
)


def _cfg(**kw):
    kw.setdefault("n_ops", 30)  # keep tier-1 fast; CI matrix runs bigger
    return SimConfig(**kw)


# -- determinism ---------------------------------------------------------------


def test_same_seed_identical_trace():
    a = run_sim(_cfg(seed=11))
    b = run_sim(_cfg(seed=11))
    assert a.ok and b.ok
    assert a.trace_hash == b.trace_hash
    assert a.steps == b.steps and a.ops_applied == b.ops_applied


def test_different_seeds_diverge():
    a = run_sim(_cfg(seed=1))
    b = run_sim(_cfg(seed=2))
    assert a.trace_hash != b.trace_hash


@pytest.mark.parametrize("scenario", SIM_SCENARIOS)
def test_every_scenario_clean_and_deterministic(scenario):
    cfg = _cfg(seed=5, scenario=scenario)
    a = run_sim(cfg)
    assert a.ok, a.violations[:3]
    assert run_sim(cfg).trace_hash == a.trace_hash


def test_sim_traffic_seeded_and_scenario_shaped():
    t1 = sim_traffic("skewed_reuse", 9, n_ops=20, n_clients=3)
    t2 = sim_traffic("skewed_reuse", 9, n_ops=20, n_clients=3)
    assert t1 == t2  # fully determined by (scenario, seed, sizes)
    assert len(t1) == 3 and all(len(ops) == 20 for ops in t1)
    assert t1 != sim_traffic("skewed_reuse", 10, n_ops=20, n_clients=3)
    with pytest.raises(ValueError):
        sim_traffic("nope", 0)


# -- fault plans under guards --------------------------------------------------


@pytest.mark.parametrize("fault", [f for f in FAULT_PLANS if f != "none"])
def test_fault_plans_clean_under_guards(fault):
    r = run_sim(_cfg(seed=3, fault=fault))
    assert r.ok, r.violations[:3]
    if fault in ("crash_restart", "replica_lag"):
        assert r.interceptor["failed_calls"] > 0  # the fault actually bit
    if fault == "hedge_timeout":
        assert r.router_metrics is not None
        assert r.router_metrics["requests"] > 0


def test_replica_lag_guard_blocks_stale_reads():
    """Under the sync-ack guard the lag fault plan charges latency but can
    never surface a stale version; the deferred-write channel stays unused."""
    r = run_sim(_cfg(seed=3, fault="replica_lag"))
    assert r.ok
    assert r.interceptor["deferred_writes"] == 0  # guard: no async replicas
    ablated = run_sim(_cfg(seed=3, fault="replica_lag", ablate=("replica_ack",)))
    assert ablated.interceptor["deferred_writes"] > 0


# -- oracle teeth: every guard ablation must be CAUGHT -------------------------

EXPECTED_ORACLES = {
    "crash_restart": {"durability"},
    "replica_lag": {"linearizability", "durability"},
    "hedge_timeout": {"completeness"},
    "mid_wave_evict": {"eviction_order", "durability", "phantom"},
}


@pytest.mark.parametrize("fault,guard", sorted(ABLATION_OF.items()))
def test_guard_ablation_is_caught_by_matching_oracle(fault, guard):
    r = run_sim(_cfg(seed=3, fault=fault, ablate=(guard,)))
    assert r.violations, (
        f"{fault} with {guard} ablated produced no violations — "
        "the oracle lost its teeth"
    )
    fired = {v.oracle for v in r.violations}
    assert fired & EXPECTED_ORACLES[fault], (fault, guard, fired)


# -- replayable failure seeds --------------------------------------------------


def test_failing_seed_dumps_and_replays_identically(tmp_path, capsys):
    from repro.sim.__main__ import main

    rc = main(["--seed", "3", "--fault", "crash_restart",
               "--ablate", "crash_fallthrough", "--ops", "30",
               "--dump-dir", str(tmp_path)])
    assert rc == 1  # violations -> red
    dumps = list(tmp_path.glob("sim-repro-*.json"))
    assert len(dumps) == 1
    rc = main(["--replay", str(dumps[0]), "--dump-dir", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 0  # trace hash reproduced bit-for-bit
    assert "replay reproduced the recorded interleaving exactly" in out
    assert "VIOLATION" in out  # and the violations fire again


# -- seeded-random property sweep (hypothesis-free tier-1 analogue) ------------


def test_random_configs_agree_with_model_and_replay():
    """Mini-fuzzer: random (scenario, fault) under guards must stay clean
    and deterministic. The hypothesis twin of this test lives in
    test_property.py (runs where hypothesis is installed)."""
    for trial in range(5):
        seed = 1000 + trial
        rng = random.Random(seed)
        cfg = SimConfig(
            seed=seed,
            scenario=rng.choice(SIM_SCENARIOS),
            fault=rng.choice(FAULT_PLANS),
            n_ops=22,
        )
        r = run_sim(cfg)
        assert r.ok, (cfg, r.violations[:3])
        assert run_sim(cfg).trace_hash == r.trace_hash, cfg


# -- building blocks -----------------------------------------------------------


def test_virtual_clock_monotone():
    c = VirtualClock()
    assert c() == 0.0
    c.advance(1.5)
    assert c.time() == 1.5
    with pytest.raises(ValueError):
        c.advance(-1)


def test_step_scheduler_seeded_interleaving():
    def order_for(seed):
        sched = StepScheduler(seed, VirtualClock())
        sched.add_client("a", [{"op": i} for i in range(6)])
        sched.add_client("b", [{"op": i} for i in range(6)])
        seen = []
        sched.run(lambda step, client, op: seen.append((client, op["op"])))
        return seen

    o1, o2 = order_for(7), order_for(7)
    assert o1 == o2 and len(o1) == 12
    assert order_for(8) != o1  # different seed, different interleaving
    # both clients' ops preserve per-client order
    assert [x for c, x in o1 if c == "a"] == list(range(6))


def test_step_scheduler_deferred_actions_fire_in_order():
    clock = VirtualClock()
    sched = StepScheduler(0, clock)
    sched.add_client("a", [{"op": i} for i in range(8)])
    fired = []
    sched.defer(3, lambda: fired.append("x"))
    sched.defer(3, lambda: fired.append("y"))
    sched.run(lambda *_: None)
    assert fired == ["x", "y"]  # same due step keeps submission order


def test_trace_recorder_hash_order_sensitive():
    a, b = TraceRecorder(), TraceRecorder()
    a.record(0, "c", "x", 1)
    a.record(1, "c", "y", 2)
    b.record(1, "c", "y", 2)
    b.record(0, "c", "x", 1)
    assert a.trace_hash != b.trace_hash
    assert a.n_events == 2


def test_value_checksum_detects_torn_entry():
    v = make_value("kw", 3)
    assert not value_torn(v)
    assert value_torn({**v, "v": 4})  # version flipped without checksum
    assert value_torn({"k": "kw"})  # structurally torn


def test_model_store_mirrors_replicated_crash_semantics():
    m = ModelStore(replication=2, capacity_per_node=8)
    for i in range(3):
        m.add_node(f"cache-{i}")
    m.insert_wave([("alpha", make_value("alpha", 1))])
    owners = m.ring.nodes_for("alpha", 2)
    m.crash(owners[0])
    got, strict = m.lookup("alpha")
    assert strict and got["v"] == 1  # replica serves through the crash
    m.restart(owners[0], recover=False)  # data loss, no repair
    m.crash(owners[1])
    got, _ = m.lookup("alpha")
    assert got is None  # both copies gone: the model says so too


# -- the new distributed-cache seams directly ---------------------------------


class _CrashingInterceptor:
    def __init__(self):
        self.crashed = set()

    def call(self, node, op, fn):
        if node in self.crashed:
            raise ShardUnavailable(node)
        return fn()


def test_distributed_cache_crash_fallthrough_guard():
    ic = _CrashingInterceptor()
    dc = DistributedPlanCache(4, replication=2, capacity_per_node=64,
                              interceptor=ic)
    for i in range(20):
        dc.insert(f"kw-{i}", i)
    ic.crashed.add("cache-1")  # facade NOT told (no mark_down)
    assert all(dc.lookup(f"kw-{i}") == i for i in range(20))


def test_distributed_cache_crash_fallthrough_ablation_drops_keys():
    ic = _CrashingInterceptor()
    dc = DistributedPlanCache(4, replication=2, capacity_per_node=64,
                              interceptor=ic, ablate=("crash_fallthrough",))
    for i in range(20):
        dc.insert(f"kw-{i}", i)
    ic.crashed.add("cache-1")
    hits = sum(dc.lookup(f"kw-{i}") is not None for i in range(20))
    assert hits < 20  # the ablated facade drops the crashed shard's keys


def test_ack_policy_primary_requires_defer_channel():
    """Without a defer-capable interceptor the 'primary' ablation would
    silently degrade to synchronous 'all' semantics — the constructor
    refuses instead."""
    with pytest.raises(ValueError, match="defer"):
        DistributedPlanCache(2, ack_policy="primary")
    with pytest.raises(ValueError, match="defer"):
        DistributedPlanCache(2, ack_policy="primary",
                             interceptor=_CrashingInterceptor())  # no .defer
    with pytest.raises(ValueError):
        DistributedPlanCache(2, ack_policy="quorum")


def test_restart_node_read_repair_restores_replication():
    dc = DistributedPlanCache(4, replication=2, capacity_per_node=64)
    for i in range(30):
        dc.insert(f"kw-{i}", i)
    # crash-restart cache-2 WITH repair: its owned keys come back from peers
    repaired = dc.restart_node("cache-2", recover=True)
    assert repaired == len(dc.shards["cache-2"])
    assert all(dc.lookup(f"kw-{i}") == i for i in range(30))
    # and losing ANOTHER node afterwards still serves everything (R=2 held)
    dc.mark_down("cache-0")
    assert all(dc.lookup(f"kw-{i}") == i for i in range(30))


def test_restart_node_without_repair_loses_replication():
    dc = DistributedPlanCache(4, replication=1, capacity_per_node=64)
    for i in range(30):
        dc.insert(f"kw-{i}", i)
    held = len(dc.shards["cache-2"])
    dc.restart_node("cache-2", recover=False)
    assert len(dc.shards["cache-2"]) == 0
    if held:
        hits = sum(dc.lookup(f"kw-{i}") is not None for i in range(30))
        assert hits == 30 - held  # R=1: the restarted node's keys are gone

"""repro.sim deterministic-simulation harness: determinism, fault plans
under guards, oracle teeth (guard ablations), replayable failure seeds,
and the sim building blocks (clock / scheduler / trace / model store)."""

import random

import pytest

from repro.core.distributed_cache import DistributedPlanCache, ShardUnavailable
from repro.envs.workloads import SIM_SCENARIOS, sim_traffic
from repro.sim import (
    ABLATION_OF,
    ALL_ABLATIONS,
    EXTRA_PLAN_ABLATIONS,
    FAULT_PLANS,
    SCENARIO_ABLATION_OF,
    ModelStore,
    SimConfig,
    StepScheduler,
    TraceRecorder,
    VirtualClock,
    make_value,
    run_sim,
    value_torn,
)


def _cfg(**kw):
    kw.setdefault("n_ops", 30)  # keep tier-1 fast; CI matrix runs bigger
    return SimConfig(**kw)


# -- determinism ---------------------------------------------------------------


def test_same_seed_identical_trace():
    a = run_sim(_cfg(seed=11))
    b = run_sim(_cfg(seed=11))
    assert a.ok and b.ok
    assert a.trace_hash == b.trace_hash
    assert a.steps == b.steps and a.ops_applied == b.ops_applied


def test_different_seeds_diverge():
    a = run_sim(_cfg(seed=1))
    b = run_sim(_cfg(seed=2))
    assert a.trace_hash != b.trace_hash


@pytest.mark.parametrize("scenario", SIM_SCENARIOS)
def test_every_scenario_clean_and_deterministic(scenario):
    cfg = _cfg(seed=5, scenario=scenario)
    a = run_sim(cfg)
    assert a.ok, a.violations[:3]
    assert run_sim(cfg).trace_hash == a.trace_hash


def test_sim_traffic_seeded_and_scenario_shaped():
    t1 = sim_traffic("skewed_reuse", 9, n_ops=20, n_clients=3)
    t2 = sim_traffic("skewed_reuse", 9, n_ops=20, n_clients=3)
    assert t1 == t2  # fully determined by (scenario, seed, sizes)
    assert len(t1) == 3 and all(len(ops) == 20 for ops in t1)
    assert t1 != sim_traffic("skewed_reuse", 10, n_ops=20, n_clients=3)
    with pytest.raises(ValueError):
        sim_traffic("nope", 0)


# -- fault plans under guards --------------------------------------------------


@pytest.mark.parametrize("fault", [f for f in FAULT_PLANS if f != "none"])
def test_fault_plans_clean_under_guards(fault):
    r = run_sim(_cfg(seed=3, fault=fault))
    assert r.ok, r.violations[:3]
    if fault in ("crash_restart", "replica_lag", "membership_churn"):
        assert r.interceptor["failed_calls"] > 0  # the fault actually bit
    if fault == "hedge_timeout":
        assert r.router_metrics is not None
        assert r.router_metrics["requests"] > 0
    if fault == "async_cachegen":
        # the pool was exercised AND the saturation bursts forced the
        # guarded synchronous fallback — with zero dropped waves
        assert r.cachegen is not None and r.cachegen["submitted"] > 0
        assert r.cachegen["rejected"] > 0
        assert r.router_metrics["async_cachegens"] > 0
        assert r.router_metrics["sync_cachegen_fallbacks"] > 0
        assert r.router_metrics["cachegen_dropped"] == 0
    if fault == "cold_tier":
        # the tier really cycled: capacity victims spilled, exact misses
        # promoted back, and the armed spill-wave crashes lost their
        # entries WHOLE on both sides (the run is still clean)
        assert r.cold_stats["spills"] > 0
        assert r.cold_stats["promotes"] > 0
        assert r.cold_stats["cold_hits"] == r.cold_stats["promotes"]
    if fault == "ttl_churn":
        # expiry really bit: lookups crossed the TTL horizon and missed,
        # and the model agreed on every expire-on-touch decision
        assert r.store_stats["misses"] > 0
    if fault == "speculative_exec":
        s = r.speculation
        assert s is not None and s["begun"] > 0  # near-hits really speculated
        assert s["pending"] == 0 and s["forced_commits"] == 0
        assert s["commits"] == s["verifier_agreed"]
        assert s["begun"] == s["commits"] + s["rollbacks"]
        # every rolled-back env write was compensated; only committed
        # writes survive in the workspace
        assert s["ws_compensations"] == s["rollbacks"]
        assert s["ws_keys"] == s["commits"]
        # the pool-saturation bursts rejected verify submissions, which
        # the fallback guard resolved synchronously instead of dropping
        assert r.router_metrics["spec_sync_verifies"] > 0
        assert r.router_metrics["spec_dropped"] == 0


def test_replica_lag_guard_blocks_stale_reads():
    """Under the sync-ack guard the lag fault plan charges latency but can
    never surface a stale version; the deferred-write channel stays unused."""
    r = run_sim(_cfg(seed=3, fault="replica_lag"))
    assert r.ok
    assert r.interceptor["deferred_writes"] == 0  # guard: no async replicas
    ablated = run_sim(_cfg(seed=3, fault="replica_lag", ablate=("replica_ack",)))
    assert ablated.interceptor["deferred_writes"] > 0


# -- oracle teeth: every guard ablation must be CAUGHT -------------------------

EXPECTED_ORACLES = {
    "crash_restart": {"durability"},
    "replica_lag": {"linearizability", "durability", "control_plane"},
    "hedge_timeout": {"completeness"},
    "mid_wave_evict": {"eviction_order", "durability", "phantom"},
    "membership_churn": {"durability", "linearizability", "control_plane"},
    "async_cachegen": {"cachegen_loss"},
    # age-rotated gc deletes live cold segments: templates the model says
    # are promotable come back MISS
    "cold_tier": {"durability"},
    # serving expired entries: values the model already expired come back
    "ttl_churn": {"phantom", "control_plane"},
    # forced commits: rolled-back speculations leak writes/metrics
    "speculative_exec": {"spec_leak"},
}


@pytest.mark.parametrize("fault,guard", sorted(ABLATION_OF.items()))
def test_guard_ablation_is_caught_by_matching_oracle(fault, guard):
    r = run_sim(_cfg(seed=3, fault=fault, ablate=(guard,)))
    assert r.violations, (
        f"{fault} with {guard} ablated produced no violations — "
        "the oracle lost its teeth"
    )
    fired = {v.oracle for v in r.violations}
    assert fired & EXPECTED_ORACLES[fault], (fault, guard, fired)


@pytest.mark.parametrize("scenario,guard", sorted(SCENARIO_ABLATION_OF.items()))
def test_scenario_guard_ablation_is_caught(scenario, guard):
    """Scenario-tied guards (the fuzzy scatter) are audited at
    replication=1, where a lost scatter has no replica tier to hide
    behind: the similarity-aware model still resolves the paraphrase, the
    ablated store cannot — a durability violation."""
    r = run_sim(_cfg(seed=3, scenario=scenario, replication=1,
                     ablate=(guard,)))
    assert r.violations, f"{scenario} with {guard} ablated stayed green"
    assert {v.oracle for v in r.violations} & {"durability"}


# -- replayable failure seeds --------------------------------------------------


def test_failing_seed_dumps_and_replays_identically(tmp_path, capsys):
    from repro.sim.__main__ import main

    rc = main(["--seed", "3", "--fault", "crash_restart",
               "--ablate", "crash_fallthrough", "--ops", "30",
               "--dump-dir", str(tmp_path)])
    assert rc == 1  # violations -> red
    dumps = list(tmp_path.glob("sim-repro-*.json"))
    assert len(dumps) == 1
    rc = main(["--replay", str(dumps[0]), "--dump-dir", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 0  # trace hash reproduced bit-for-bit
    assert "replay reproduced the recorded interleaving exactly" in out
    assert "VIOLATION" in out  # and the violations fire again


# -- seeded-random property sweep (hypothesis-free tier-1 analogue) ------------


def test_random_configs_agree_with_model_and_replay():
    """Mini-fuzzer: random (scenario, fault) under guards must stay clean
    and deterministic. The hypothesis twin of this test lives in
    test_property.py (runs where hypothesis is installed)."""
    for trial in range(5):
        seed = 1000 + trial
        rng = random.Random(seed)
        cfg = SimConfig(
            seed=seed,
            scenario=rng.choice(SIM_SCENARIOS),
            fault=rng.choice(FAULT_PLANS),
            n_ops=22,
        )
        r = run_sim(cfg)
        assert r.ok, (cfg, r.violations[:3])
        assert run_sim(cfg).trace_hash == r.trace_hash, cfg


# -- building blocks -----------------------------------------------------------


def test_virtual_clock_monotone():
    c = VirtualClock()
    assert c() == 0.0
    c.advance(1.5)
    assert c.time() == 1.5
    with pytest.raises(ValueError):
        c.advance(-1)


def test_step_scheduler_seeded_interleaving():
    def order_for(seed):
        sched = StepScheduler(seed, VirtualClock())
        sched.add_client("a", [{"op": i} for i in range(6)])
        sched.add_client("b", [{"op": i} for i in range(6)])
        seen = []
        sched.run(lambda step, client, op: seen.append((client, op["op"])))
        return seen

    o1, o2 = order_for(7), order_for(7)
    assert o1 == o2 and len(o1) == 12
    assert order_for(8) != o1  # different seed, different interleaving
    # both clients' ops preserve per-client order
    assert [x for c, x in o1 if c == "a"] == list(range(6))


def test_step_scheduler_deferred_actions_fire_in_order():
    clock = VirtualClock()
    sched = StepScheduler(0, clock)
    sched.add_client("a", [{"op": i} for i in range(8)])
    fired = []
    sched.defer(3, lambda: fired.append("x"))
    sched.defer(3, lambda: fired.append("y"))
    sched.run(lambda *_: None)
    assert fired == ["x", "y"]  # same due step keeps submission order


def test_trace_recorder_hash_order_sensitive():
    a, b = TraceRecorder(), TraceRecorder()
    a.record(0, "c", "x", 1)
    a.record(1, "c", "y", 2)
    b.record(1, "c", "y", 2)
    b.record(0, "c", "x", 1)
    assert a.trace_hash != b.trace_hash
    assert a.n_events == 2


def test_value_checksum_detects_torn_entry():
    v = make_value("kw", 3)
    assert not value_torn(v)
    assert value_torn({**v, "v": 4})  # version flipped without checksum
    assert value_torn({"k": "kw"})  # structurally torn


def test_model_store_mirrors_replicated_crash_semantics():
    m = ModelStore(replication=2, capacity_per_node=8)
    for i in range(3):
        m.add_node(f"cache-{i}")
    m.insert_wave([("alpha", make_value("alpha", 1))])
    owners = m.ring.nodes_for("alpha", 2)
    m.crash(owners[0])
    got, strict = m.lookup("alpha")
    assert strict and got["v"] == 1  # replica serves through the crash
    m.restart(owners[0], recover=False)  # data loss, no repair
    m.crash(owners[1])
    got, _ = m.lookup("alpha")
    assert got is None  # both copies gone: the model says so too


# -- the new distributed-cache seams directly ---------------------------------


class _CrashingInterceptor:
    def __init__(self):
        self.crashed = set()

    def call(self, node, op, fn):
        if node in self.crashed:
            raise ShardUnavailable(node)
        return fn()


def test_distributed_cache_crash_fallthrough_guard():
    ic = _CrashingInterceptor()
    dc = DistributedPlanCache(4, replication=2, capacity_per_node=64,
                              interceptor=ic)
    for i in range(20):
        dc.insert(f"kw-{i}", i)
    ic.crashed.add("cache-1")  # facade NOT told (no mark_down)
    assert all(dc.lookup(f"kw-{i}") == i for i in range(20))


def test_distributed_cache_crash_fallthrough_ablation_drops_keys():
    ic = _CrashingInterceptor()
    dc = DistributedPlanCache(4, replication=2, capacity_per_node=64,
                              interceptor=ic, ablate=("crash_fallthrough",))
    for i in range(20):
        dc.insert(f"kw-{i}", i)
    ic.crashed.add("cache-1")
    hits = sum(dc.lookup(f"kw-{i}") is not None for i in range(20))
    assert hits < 20  # the ablated facade drops the crashed shard's keys


def test_ack_policy_primary_requires_defer_channel():
    """Without a defer-capable interceptor the 'primary' ablation would
    silently degrade to synchronous 'all' semantics — the constructor
    refuses instead."""
    with pytest.raises(ValueError, match="defer"):
        DistributedPlanCache(2, ack_policy="primary")
    with pytest.raises(ValueError, match="defer"):
        DistributedPlanCache(2, ack_policy="primary",
                             interceptor=_CrashingInterceptor())  # no .defer
    with pytest.raises(ValueError):
        DistributedPlanCache(2, ack_policy="quorum")


def test_restart_node_read_repair_restores_replication():
    dc = DistributedPlanCache(4, replication=2, capacity_per_node=64)
    for i in range(30):
        dc.insert(f"kw-{i}", i)
    # crash-restart cache-2 WITH repair: its owned keys come back from peers
    repaired = dc.restart_node("cache-2", recover=True)
    assert repaired == len(dc.shards["cache-2"])
    assert all(dc.lookup(f"kw-{i}") == i for i in range(30))
    # and losing ANOTHER node afterwards still serves everything (R=2 held)
    dc.mark_down("cache-0")
    assert all(dc.lookup(f"kw-{i}") == i for i in range(30))


def test_restart_node_without_repair_loses_replication():
    dc = DistributedPlanCache(4, replication=1, capacity_per_node=64)
    for i in range(30):
        dc.insert(f"kw-{i}", i)
    held = len(dc.shards["cache-2"])
    dc.restart_node("cache-2", recover=False)
    assert len(dc.shards["cache-2"]) == 0
    if held:
        hits = sum(dc.lookup(f"kw-{i}") is not None for i in range(30))
        assert hits == 30 - held  # R=1: the restarted node's keys are gone


# -- control-plane ops through the interceptor seam ----------------------------


def test_control_plane_ops_pay_and_fail_rpcs():
    """keys/len/autotune/clear go through the same per-shard seam as the
    data plane: they charge interceptor calls, and an unreachable shard is
    skipped — invisible to scans, untouched by clear."""
    ic = _CrashingInterceptor()
    dc = DistributedPlanCache(4, replication=1, capacity_per_node=64,
                              interceptor=ic)
    for i in range(24):
        dc.insert(f"kw-{i}", i)
    held = len(dc.shards["cache-1"])
    assert held > 0  # 24 keys over 4 shards: cache-1 owns some

    ic.crashed.add("cache-1")
    visible = dc.keys()
    assert len(visible) == 24 - held  # unreachable keys are invisible
    assert len(dc) == len(visible)
    assert dc.autotune() == []  # runs, skipping the crashed shard

    # clear wipes only reachable shards: the crashed one keeps stale data
    dc.clear()
    assert len(dc.shards["cache-1"]) == held
    ic.crashed.discard("cache-1")
    assert len(dc) == held  # ...which becomes visible again on recovery
    dc.restart_node("cache-1", recover=False)  # restart wipes the staleness
    assert len(dc) == 0


def test_graceful_drain_of_unreachable_node_is_crash_style():
    """remove_node's drain scan goes through the seam: an unreachable
    node cannot donate its keys, so they are lost with it (replicas
    permitting), never silently re-homed from data we could not read."""
    ic = _CrashingInterceptor()
    dc = DistributedPlanCache(4, replication=1, capacity_per_node=64,
                              interceptor=ic)
    for i in range(24):
        dc.insert(f"kw-{i}", i)
    held = len(dc.shards["cache-1"])
    ic.crashed.add("cache-1")
    dc.remove_node("cache-1")
    assert "cache-1" not in dc.shards
    ic.crashed.discard("cache-1")
    hits = sum(dc.lookup(f"kw-{i}") is not None for i in range(24))
    assert hits == 24 - held


def test_churn_rehome_ablation_loses_moved_keys():
    """With the churn-rehoming guard ablated, a join does not rebalance
    and a drain drops its data — at R=1 that is directly observable."""
    dc = DistributedPlanCache(4, replication=1, capacity_per_node=64,
                              ablate=("churn_rehome",))
    for i in range(30):
        dc.insert(f"kw-{i}", i)
    dc.add_node("cache-9")  # no rebalance: keys whose owner moved are lost
    hits = sum(dc.lookup(f"kw-{i}") is not None for i in range(30))
    assert hits < 30

    ok = DistributedPlanCache(4, replication=1, capacity_per_node=64)
    for i in range(30):
        ok.insert(f"kw-{i}", i)
    ok.add_node("cache-9")  # the guarded store re-homes
    assert all(ok.lookup(f"kw-{i}") is not None for i in range(30))


# -- membership churn vs the ring-change-mirroring model -----------------------


def test_membership_churn_plan_clean_and_deterministic():
    for scenario in ("skewed_reuse", "paraphrase_burst"):
        cfg = _cfg(seed=7, scenario=scenario, fault="membership_churn")
        r = run_sim(cfg)
        assert r.ok, (scenario, r.violations[:3])
        assert run_sim(cfg).trace_hash == r.trace_hash


def test_model_store_join_and_drain_mirror_ring_changes():
    m = ModelStore(replication=2, capacity_per_node=64)
    for i in range(3):
        m.add_node(f"cache-{i}")
    m.insert_wave([(f"kw-{i}", make_value(f"kw-{i}", 1)) for i in range(20)])
    m.join("cache-3")  # rebalance: every key still resolvable
    assert all(m.lookup(f"kw-{i}")[0] is not None for i in range(20))
    m.drain("cache-0")  # graceful: keys re-homed before the node drops
    assert "cache-0" not in m.nodes
    assert all(m.lookup(f"kw-{i}")[0] is not None for i in range(20))
    # a crashed node drains crash-style: its copies are lost with it
    m.crash("cache-1")
    m.drain("cache-1")
    assert "cache-1" not in m.nodes


# -- async cache-generation under the scheduler --------------------------------


def test_async_cachegen_plan_clean_and_race_actually_interleaved():
    """The admission race is real: distilled waves land at scheduler-chosen
    later steps (worker clients), interleaved with lookups/removals, and
    the model mirrored every wave at its landing step."""
    cfg = _cfg(seed=5, fault="async_cachegen")
    r = run_sim(cfg)
    assert r.ok, r.violations[:3]
    assert run_sim(cfg).trace_hash == r.trace_hash
    assert r.cachegen["submitted"] > 0
    # async mode really deferred work: more scheduler steps than the pure
    # client-op count (each submitted wave is one extra worker op)
    assert r.ops_applied > cfg.n_ops * cfg.n_clients


def test_async_admission_race_regression_pinned_seed(tmp_path, capsys):
    """Regression pin for the async admission race: the ablated router
    drops saturated waves, the cachegen_loss oracle fires, and the dumped
    seed replays bit-for-bit (the repro workflow operators rely on)."""
    from repro.sim.__main__ import main

    rc = main(["--seed", "3", "--fault", "async_cachegen",
               "--ablate", "cachegen_fallback", "--ops", "30",
               "--dump-dir", str(tmp_path)])
    assert rc == 1
    out = capsys.readouterr().out
    assert "cachegen_loss" in out
    dumps = list(tmp_path.glob("sim-repro-*.json"))
    assert len(dumps) == 1
    rc = main(["--replay", str(dumps[0]), "--dump-dir", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "replay reproduced the recorded interleaving exactly" in out
    assert "cachegen_loss" in out


# -- tiered memory: cold tier + ttl plans --------------------------------------


def test_cold_tier_plan_clean_deterministic_and_cycling():
    """The cold_tier plan drives real spill/promote traffic (on-disk
    CheckpointStore segments under a throwaway dir) with two armed
    spill-wave crashes — and stays clean, deterministic, and replayable:
    no template is ever both lost and unevicted."""
    cfg = _cfg(seed=7, fault="cold_tier")
    r = run_sim(cfg)
    assert r.ok, r.violations[:3]
    assert r.config.cold_tier and r.config.n_nodes == 1
    assert r.cold_stats["spills"] > r.cold_stats["promotes"] > 0
    b = run_sim(cfg)
    assert (b.trace_hash, b.span_digest) == (r.trace_hash, r.span_digest)


def test_cold_crash_loses_wave_whole_on_both_sides(tmp_path):
    """A crash between segment write and manifest commit loses the spill
    wave WHOLE — the manifest never references the orphan segment, and the
    sim cell with two such armed crashes is as clean as one without."""
    from repro.memory import ColdTier

    ct = ColdTier(str(tmp_path))
    ct.arm_crash_after_segment(1)
    ct.spill([("a", 1, None, None, 0.0), ("b", 2, None, None, 0.0)])
    assert len(ct) == 0  # both entries lost together, none half-committed
    assert ct.fetch(["a", "b"]) == [None, None]
    ct.spill([("c", 3, None, None, 0.0)])  # disarmed: the next wave lands
    assert "c" in ct and ct.take(["c"])[0].value == 3

    r = run_sim(_cfg(seed=1, fault="cold_tier"))  # plan arms two crashes
    assert r.ok, r.violations[:3]


def test_ttl_churn_plan_clean_and_expiry_bites():
    cfg = _cfg(seed=9, fault="ttl_churn")
    r = run_sim(cfg)
    assert r.ok, r.violations[:3]
    assert r.config.ttl_s == 0.05 and not r.config.fuzzy
    assert r.store_stats["misses"] > 0  # expiry-vs-lookup races happened
    assert run_sim(cfg).span_digest == r.span_digest


def test_conditional_admission_regression_pinned_seed():
    """Regression pin for insert-if-newer (§4.3 admission race): under the
    async_cachegen plan, distilled waves carry the token their lookup read;
    every key a client re-wrote in the interim is SKIPPED — the model
    replays each skip decision, so the run stays linearizable with a
    nonzero skip count, bit-for-bit reproducible."""
    cfg = _cfg(seed=3, fault="async_cachegen")
    r = run_sim(cfg)
    assert r.ok, r.violations[:3]
    assert r.cold_stats["stale_insert_skips"] > 0  # the race really ran
    b = run_sim(cfg)
    assert (b.trace_hash, b.span_digest) == (r.trace_hash, r.span_digest)
    assert b.cold_stats["stale_insert_skips"] == r.cold_stats["stale_insert_skips"]


# -- strict paraphrase scenarios (similarity-aware model) ----------------------


def test_paraphrase_scenario_is_strict_and_fuzzy_hits_happen():
    cfg = _cfg(seed=2, scenario="paraphrase_burst")
    r = run_sim(cfg)
    assert r.ok, r.violations[:3]
    assert r.config.fuzzy  # normalized() arms the fuzzy pipeline
    assert r.store_stats["hits"] > 0  # paraphrases resolved, strictly checked


def test_similarity_model_predicts_fuzzy_resolution():
    m = ModelStore(replication=1, capacity_per_node=64, fuzzy=True)
    for i in range(2):
        m.add_node(f"cache-{i}")
    v = make_value("average of two rows", 1)
    m.insert_wave([("average of two rows", v)])
    got, strict = m.lookup("average of two rows from table")
    assert strict  # similarity-aware: paraphrase predictions are exact
    assert got == v  # resolves to the canonical entry (cosine >= 0.8)
    got, strict = m.lookup("entirely unrelated query zz")
    assert got is None and strict  # and sub-threshold misses are strict too


# -- speculative execution: fault plan, oracles, guard ablations ---------------


def test_extra_plan_ablations_well_formed():
    """Every extra-guard audit cell points at a real fault plan, a guard
    the CLI accepts, and a guard DIFFERENT from the plan's primary one
    (otherwise the extra cell would be a duplicate audit)."""
    for fault, guard in EXTRA_PLAN_ABLATIONS.items():
        assert fault in FAULT_PLANS
        assert guard in ALL_ABLATIONS
        assert guard != ABLATION_OF.get(fault)


def test_spec_rollback_ablation_fires_leak_oracle_only():
    """With the rollback guard ablated every disagreeing verification is
    FORCED to commit: its env write survives in the workspace and its
    deferred metric/admission actions run — the spec_leak oracle must
    attribute both, and liveness must stay green (everything resolved)."""
    r = run_sim(_cfg(seed=3, fault="speculative_exec",
                     ablate=("spec_rollback",)))
    assert r.violations
    assert {v.oracle for v in r.violations} == {"spec_leak"}
    assert r.speculation["forced_commits"] > 0
    assert r.speculation["pending"] == 0


def test_spec_verify_timeout_ablation_fires_liveness_oracle_only():
    """With the verify-timeout fallback ablated, a pool-rejected verify
    submission is dropped and its speculation stays pending forever — the
    spec_liveness oracle must fire, and spec_leak must NOT (a pending
    speculation's write is not a leak; it was never rolled back)."""
    r = run_sim(_cfg(seed=3, fault="speculative_exec",
                     ablate=("spec_verify_timeout",)))
    assert r.violations
    assert {v.oracle for v in r.violations} == {"spec_liveness"}
    assert r.speculation["pending"] > 0
    assert r.router_metrics["spec_dropped"] > 0


def test_spec_commit_vs_concurrent_overwrite_regression_pinned_seed():
    """Regression pin for the nastiest speculation race: a speculation
    COMMITS while the plan-cache entry it adapted was concurrently
    re-written (another speculation on the same keyword, or a distilled
    wave, landed first). The deferred admission carries the route-time
    token, so it must LOSE to the newer write per node — the model
    replays every skip decision, the run stays clean, and the whole
    interleaving reproduces bit-for-bit."""
    cfg = _cfg(seed=3, fault="speculative_exec")
    r = run_sim(cfg)
    assert r.ok, r.violations[:3]
    assert r.speculation["stale_admit_races"] > 0  # the race really ran
    assert r.speculation["commits"] > 0
    b = run_sim(cfg)
    assert (b.trace_hash, b.span_digest) == (r.trace_hash, r.span_digest)
    assert b.speculation == r.speculation


# -- intra-wave grouped recency mirroring --------------------------------------


def test_model_mirrors_intra_wave_grouped_recency():
    """Within ONE batched wave the store touches recency grouped per
    shard per tier: a fuzzy-scatter resolution (tier 1) lands AFTER a
    later wave-member's tier-0 exact touch on the same shard. The old
    per-query mirror replayed wave order and predicted the opposite LRU
    victim; the grouped mirror must agree with the store — and the
    singular-lookup control shows the divergence is real, not vacuous."""
    from repro.core.fuzzy import similarity

    pairs = [
        ("average of two rows", "average of two rows from table"),
        ("sum of one column", "sum of one column from table"),
        ("max minus min", "max minus min from table"),
    ]
    fillers = ["paint the fence bright green", "solve the quadratic equation",
               "walk the dog around town", "bake the sourdough loaf",
               "tune the violin strings", "chart the ocean currents"]

    def build():
        return DistributedPlanCache(2, replication=1, capacity_per_node=2,
                                    fuzzy=True)

    ring = build().ring
    chosen = None
    for x, q in pairs:
        if similarity(x, q) < 0.8:
            continue  # pair must resolve at the fuzzy threshold
        if ring.nodes_for(x, 1) == ring.nodes_for(q, 1):
            continue  # pair must split across shards for the tier skew
        neutral = [f for f in fillers
                   if ring.nodes_for(f, 1) == ring.nodes_for(x, 1)
                   and similarity(f, q) < 0.8 and similarity(f, x) < 0.8]
        if len(neutral) >= 2:
            chosen = (x, q, neutral[0], neutral[1])
            break
    assert chosen, "no shard-splitting paraphrase pair found (embed changed?)"
    x, q, y, z = chosen

    def play(batched):
        dc = build()
        m = ModelStore(replication=1, capacity_per_node=2, fuzzy=True)
        for i in range(2):
            m.add_node(f"cache-{i}")
        seed = [(x, make_value(x, 1)), (y, make_value(y, 1))]
        dc.insert_batch(seed)
        m.insert_wave(seed)
        if batched:  # q resolves fuzzily on x's shard AFTER y's touch
            got = dc.lookup_batch([q, y])
            want = [v for v, _ in m.lookup_wave([q, y])]
        else:  # control: per-query order touches x BEFORE y
            got = [dc.lookup(q), dc.lookup(y)]
            want = [m.lookup(q)[0], m.lookup(y)[0]]
        assert got == want
        dc.insert(z, make_value(z, 1))  # capacity 2: one LRU victim falls
        m.insert_wave([(z, make_value(z, 1))])
        assert sorted(dc.keys()) == m.keys()  # same victim on both sides
        return sorted(dc.keys())

    assert play(True) != play(False)  # the grouping really moves the victim

"""Tier-1 tests for the ``tools.analyze`` invariant-checker suite.

Golden violating/clean fixture pairs live in
``tests/fixtures/analysis/``: each checker must fire on its violating
fixture (the guard-ablation direction — delete the guard and the
checker catches it) and stay silent on the clean fixture that encodes
the repo's real idioms (seam references, helper-under-lock,
rebind-from-result donation, context-managed pools).
"""

from __future__ import annotations

import json
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
FIX = ROOT / "tests" / "fixtures" / "analysis"

from tools.analyze import CHECKER_IDS  # noqa: E402
from tools.analyze.common import fingerprint  # noqa: E402
from tools.analyze.gates import (  # noqa: E402
    DEFAULT_TARGET,
    PRAGMA_HYGIENE_ID,
    PRAGMAS_OF_CHECKER,
    analyze_paths,
)


def findings_for(name):
    findings, n_files = analyze_paths([FIX / name])
    assert n_files == 1, f"{name} failed to parse"
    return findings


PAIRS = [
    ("lock-discipline", "lock_violation.py", "lock_clean.py"),
    ("determinism", "clock_violation.py", "clock_clean.py"),
    ("jit-safety", "jit_violation.py", "jit_clean.py"),
    ("obs-names", "obs_violation.py", "obs_clean.py"),
    ("thread-hygiene", "thread_violation.py", "thread_clean.py"),
    ("journal-discipline", "journal_violation.py", "journal_clean.py"),
]


@pytest.mark.parametrize("checker,violating,clean", PAIRS,
                         ids=[p[0] for p in PAIRS])
def test_golden_pair(checker, violating, clean):
    bad = findings_for(violating)
    assert bad, f"{violating} tripped nothing"
    assert {f.checker for f in bad} == {checker}, \
        f"{violating} tripped other checkers: {[f.render() for f in bad]}"
    good = findings_for(clean)
    assert good == [], \
        f"{clean} must be clean: {[f.render() for f in good]}"


def test_lock_discipline_details():
    bad = findings_for("lock_violation.py")
    msgs = "\n".join(f.message for f in bad)
    # the direct unheld writes AND the transitive unheld call site
    assert "Counter.bump writes self.count" in msgs
    assert "Counter._bump_unlocked writes self.count" in msgs
    assert "Counter.caller calls self._bump_unlocked()" in msgs


def test_determinism_details():
    bad = findings_for("clock_violation.py")
    msgs = "\n".join(f.message for f in bad)
    assert "time.time()" in msgs
    assert "random.random()" in msgs
    assert "default_rng" in msgs
    assert "np.random.shuffle" in msgs
    assert len(bad) == 4


def test_jit_safety_details():
    bad = findings_for("jit_violation.py")
    msgs = "\n".join(f.message for f in bad)
    assert "print() inside a jax.jit body" in msgs
    assert "`STATE['calls']`" in msgs
    assert "pallas kernel body" in msgs
    assert "donated to scatter()" in msgs


def test_journal_discipline_details():
    bad = findings_for("journal_violation.py")
    msgs = "\n".join(f.message for f in bad)
    assert "`ws.write(...)` is not journaled" in msgs
    assert "`task.workspace.delete(...)` is not journaled" in msgs
    assert len(bad) == 3  # discarded undo, parked undo, chained delete


def test_thread_hygiene_details():
    bad = findings_for("thread_violation.py")
    msgs = "\n".join(f.message for f in bad)
    assert "no .shutdown(...) on `pool`" in msgs
    assert "no .join(...) or daemon=True on `t`" in msgs
    assert "without a binding" in msgs
    assert len(bad) == 3


# -- pragmas ----------------------------------------------------------------


def test_pragma_suppresses_and_counts_as_used():
    assert findings_for("pragma_used.py") == []


def test_unused_pragma_is_flagged():
    out = findings_for("pragma_unused.py")
    assert [f.checker for f in out] == [PRAGMA_HYGIENE_ID]
    assert "suppresses nothing" in out[0].message


def test_malformed_pragmas_are_flagged():
    out = findings_for("pragma_bad.py")
    assert {f.checker for f in out} == {PRAGMA_HYGIENE_ID}
    msgs = "\n".join(f.message for f in out)
    assert "has no reason" in msgs
    assert "unknown pragma kind `wibble-ok`" in msgs


# -- fingerprints -----------------------------------------------------------


def test_fingerprint_is_line_number_independent(tmp_path):
    src = (FIX / "clock_violation.py").read_text()
    shifted = tmp_path / "clock_violation.py"  # same basename, same rel key
    shifted.write_text("# pad\n# pad\n# pad\n" + src)
    base, _ = analyze_paths([FIX / "clock_violation.py"])
    moved, _ = analyze_paths([shifted])
    # same content hashed under different paths: compare the content half
    # by re-fingerprinting under a fixed file key
    def content_prints(findings, lines):
        return sorted(
            fingerprint(f.checker, "K", lines[f.line - 1].strip(), 0)
            for f in findings
        )
    assert content_prints(base, src.splitlines()) == \
        content_prints(moved, shifted.read_text().splitlines())
    assert [f.line for f in moved] == [f.line + 3 for f in base]


def test_fingerprints_are_stable_and_unique():
    out = findings_for("clock_violation.py")
    prints = [f.fingerprint for f in out]
    assert len(set(prints)) == len(prints)
    again = [f.fingerprint for f in findings_for("clock_violation.py")]
    assert prints == again


# -- the tree itself --------------------------------------------------------


def test_src_repro_is_clean():
    findings, n_files = analyze_paths([DEFAULT_TARGET])
    assert n_files > 50
    assert findings == [], "\n".join(f.render() for f in findings)


def test_checker_catalog_matches_registry():
    ids = set(PRAGMAS_OF_CHECKER) | {PRAGMA_HYGIENE_ID}
    assert ids == set(CHECKER_IDS)


# -- CLI --------------------------------------------------------------------


def _cli(*args, cwd=ROOT):
    return subprocess.run(
        [sys.executable, "-m", "tools.analyze", *args],
        capture_output=True, text=True, cwd=cwd, timeout=120,
    )


def test_cli_help_exits_zero():
    r = _cli("--help")
    assert r.returncode == 0
    assert "--gate" in r.stdout


def test_cli_violating_fixture_fails_with_json_report(tmp_path):
    report = tmp_path / "report.json"
    r = _cli(str(FIX / "clock_violation.py"), "--json", str(report))
    assert r.returncode == 1
    doc = json.loads(report.read_text())
    assert doc["gate"] == "analyze"
    assert doc["files_checked"] == 1
    assert doc["baselined"] == 0
    assert len(doc["findings"]) == 4
    f = doc["findings"][0]
    assert set(f) == {"checker", "file", "line", "col", "message",
                      "fingerprint"}


def test_cli_baseline_grandfathers_findings(tmp_path):
    baseline = tmp_path / "baseline.json"
    r = _cli(str(FIX / "clock_violation.py"),
             "--baseline", str(baseline), "--write-baseline")
    assert r.returncode == 0
    doc = json.loads(baseline.read_text())
    assert len(doc["fingerprints"]) == 4
    r2 = _cli(str(FIX / "clock_violation.py"), "--baseline", str(baseline))
    assert r2.returncode == 0
    assert "4 baselined" in r2.stdout


def test_cli_single_checker_filter():
    r = _cli(str(FIX / "clock_violation.py"), "--checker", "thread-hygiene")
    assert r.returncode == 0  # no thread findings in the clock fixture
    r2 = _cli(str(FIX / "clock_violation.py"), "--checker", "determinism")
    assert r2.returncode == 1

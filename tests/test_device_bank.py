"""DeviceBank / device backend: host-device lockstep, donation safety,
kernel parity, and the batched admission paths built on top of it.

The device arena is updated through donated jit'd scatters — these tests
pin the three ways that could go wrong: the mirror drifting from the host
arena under interleaved mutation, donation corrupting rows that the
freelist later reuses, and the resident top-k path disagreeing with the
numpy oracle (``kernels/ref.py``).
"""

import numpy as np
import pytest

from repro.core.cache import PlanCache
from repro.core.distributed_cache import DistributedPlanCache
from repro.index import DIM, SimilarityIndex, embed, embed_batch
from repro.index.device import DeviceBank

RNG = np.random.RandomState(13)


def _unit_rows(n, seed=0):
    m = np.random.RandomState(seed).randn(n, DIM).astype(np.float32)
    m /= np.maximum(np.linalg.norm(m, axis=1, keepdims=True), 1e-9)
    return m


def _assert_lockstep(idx: SimilarityIndex) -> None:
    """Host and device arenas agree row-for-row over the occupied prefix,
    and any host rows beyond the device's capacity are free (all-zero)."""
    dev = np.asarray(idx._device.arena)
    host = idx.bank.arena()
    n = min(dev.shape[0], host.shape[0])
    np.testing.assert_array_equal(dev[:n], host[:n])
    assert np.all(dev[n:] == 0.0) and np.all(host[n:] == 0.0)


# -- host/device arena equivalence -------------------------------------------


def test_device_mirror_interleaved_add_remove_clear():
    idx = SimilarityIndex(backend="device", initial_capacity=4)
    model = {}
    for step in range(300):
        r = RNG.rand()
        key = f"key-{RNG.randint(40)}"
        if r < 0.55:
            idx.add(key)
            model[key] = True
        elif r < 0.9:
            idx.remove(key)
            model.pop(key, None)
        else:
            if RNG.rand() < 0.1:
                idx.clear()
                model.clear()
        assert len(idx) == len(model)
    _assert_lockstep(idx)
    for k in model:
        assert idx.best_match(k, threshold=0.99) == k


def test_device_bootstrap_uploads_prefilled_bank():
    """Constructing on a bank that already has entries mirrors them in one
    batched upload instead of starting empty."""
    from repro.index import EmbeddingBank

    bank = EmbeddingBank(initial_capacity=8)
    for i in range(5):
        bank.add(f"existing key {i}")
    idx = SimilarityIndex(backend="device", bank=bank)
    _assert_lockstep(idx)
    assert idx.best_match("existing key 3", threshold=0.99) == "existing key 3"
    assert idx._device.batched_updates == 1


def test_add_batch_matches_sequential_adds():
    keys = [f"intent keyword number {i}" for i in range(37)]
    seq = SimilarityIndex(backend="device", initial_capacity=8)
    for k in keys:
        seq.add(k)
    batched = SimilarityIndex(backend="device", initial_capacity=8)
    batched.add_batch(keys)
    np.testing.assert_array_equal(
        seq.bank.matrix(), batched.bank.matrix()
    )
    _assert_lockstep(batched)
    # the whole wave crossed in one donated scatter, not 37
    assert batched._device.batched_updates == 1
    assert batched._device.row_updates == 0


# -- donation vs freelist reuse ----------------------------------------------


def test_donation_does_not_corrupt_freelist_reuse():
    idx = SimilarityIndex(backend="device", initial_capacity=4)
    for i in range(6):  # forces a host grow + device grow
        idx.add(f"topic number {i}")
    slot = idx.bank.slot_of("topic number 2")
    idx.remove("topic number 2")
    assert np.all(np.asarray(idx._device.arena)[slot] == 0.0)  # tombstoned
    # freelist hands the slot to a new key; the donated overwrite must land
    # on the device row and every *other* row must be untouched
    before = np.asarray(idx._device.arena).copy()
    idx.add("completely different replacement")
    assert idx.bank.slot_of("completely different replacement") == slot
    after = np.asarray(idx._device.arena)
    np.testing.assert_array_equal(
        after[slot], embed("completely different replacement")
    )
    mask = np.ones(after.shape[0], bool)
    mask[slot] = False
    np.testing.assert_array_equal(after[mask], before[mask])
    assert idx.best_match("completely different replacement", 0.99) is not None


def test_device_bank_growth_preserves_rows_with_zero_h2d():
    b = DeviceBank(capacity=2)
    vecs = _unit_rows(2, seed=1)
    b.set_rows([0, 1], vecs)
    h2d_before = b.h2d_bytes_total
    b.ensure_capacity(9)  # -> 16, device-side pad only
    assert b.capacity == 16
    assert b.h2d_bytes_total == h2d_before  # growth moved zero host bytes
    np.testing.assert_array_equal(np.asarray(b.arena)[:2], vecs)
    assert np.all(np.asarray(b.arena)[2:] == 0.0)


def test_device_bank_h2d_accounting():
    b = DeviceBank(capacity=8)
    b.set_row(0, _unit_rows(1)[0])
    assert b.h2d_bytes_total == DIM * 4
    b.clear_row(0)  # device-generated zeros: no upload
    assert b.h2d_bytes_total == DIM * 4
    b.clear()
    assert b.h2d_bytes_total == DIM * 4
    t = b.telemetry()
    assert t["row_updates"] == 1 and t["clears"] == 1


# -- resident top-k parity vs the numpy oracle --------------------------------


@pytest.mark.parametrize("n", [0, 1, 17, 1000])
@pytest.mark.parametrize("k", [1, 8])
def test_resident_topk_matches_ref(n, k):
    from repro.kernels import ops, ref

    queries = _unit_rows(5, seed=n * 10 + k)
    bank = _unit_rows(n, seed=n + 1)
    s, i = ops.resident_topk(queries, bank, k=k)
    rs, ri = ref.topk_cosine_ref(queries, bank, k)
    np.testing.assert_allclose(np.asarray(s), rs, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), ri)


def test_device_backend_topk_parity_vs_ref():
    """End-to-end: SimilarityIndex on the device backend returns the same
    neighbors as the numpy oracle over the host matrix."""
    from repro.kernels import ref

    M = _unit_rows(64, seed=5)
    idx = SimilarityIndex(backend="device", initial_capacity=64)
    idx.add_batch([f"k{i}" for i in range(64)], M)
    q = _unit_rows(7, seed=6)
    s, slots = idx.topk(q, k=3)
    rs, ri = ref.topk_cosine_ref(q, M, 3)
    np.testing.assert_allclose(s, rs, atol=1e-5)
    np.testing.assert_array_equal(slots, ri)


def test_device_and_brute_backends_agree_end_to_end():
    keys = [f"intent keyword number {i}" for i in range(40)]
    dev = SimilarityIndex(backend="device")
    bru = SimilarityIndex(backend="brute")
    for k in keys:
        dev.add(k)
        bru.add(k)
    queries = ["intent keyword number 7", "zz qq totally unrelated"]
    assert dev.best_match_batch(queries, 0.8) == bru.best_match_batch(queries, 0.8)
    dev.remove(keys[7])
    bru.remove(keys[7])
    assert (
        dev.best_match("intent keyword number 7", 0.99)
        == bru.best_match("intent keyword number 7", 0.99)
    )


def test_device_steady_state_lookups_move_only_queries():
    idx = SimilarityIndex(backend="device", initial_capacity=64)
    idx.add_batch([f"k{i}" for i in range(50)], _unit_rows(50, seed=2))
    before = idx.telemetry()["device"]["h2d_bytes_total"]
    q = _unit_rows(3, seed=3)
    idx.topk(q, k=2)
    moved = idx.telemetry()["device"]["h2d_bytes_total"] - before
    assert moved == 8 * DIM * 4  # the padded query batch; zero bank bytes


# -- batched admission through the cache layers -------------------------------


def test_plan_cache_insert_batch_keeps_index_in_lockstep():
    c = PlanCache(capacity=10, fuzzy=True, fuzzy_threshold=0.7,
                  index_backend="device")
    c.insert_batch([(f"metric number {i}", i) for i in range(14)])
    assert len(c) == 10  # LRU evicted the oldest 4
    assert sorted(c._matcher.index.bank.keys()) == sorted(c.keys())
    _assert_lockstep(c._matcher.index)
    assert c.lookup("metric number 13") == 13
    assert c.lookup_batch(["metric number 13 analysis"]) == [13]


def test_distributed_device_shards_and_batched_fallthrough():
    dc = DistributedPlanCache(
        n_nodes=3, replication=2, fuzzy=True, fuzzy_threshold=0.7,
        index_backend="device",
    )
    kws = [f"quarterly report metric {i}" for i in range(12)]
    dc.insert_batch([(k, i) for i, k in enumerate(kws)])
    # batched path == sequential path, including fuzzy near-misses
    probes = kws[:4] + [kws[5] + " analysis", "unrelated quantum topic"]
    assert dc.lookup_batch(probes) == [dc.lookup(p) for p in probes]
    # replica fallthrough: kill each primary in turn; batched lookups must
    # still resolve every keyword through the surviving replica tier
    for kw in kws:
        primary = dc.ring.nodes_for(kw, 1)[0]
        dc.mark_down(primary)
        assert dc.lookup_batch([kw]) == [kws.index(kw)]
        dc.mark_up(primary)


def test_router_route_batch_admission_wave(tmp_path):
    from repro.serving.router import TwoTierRouter

    cache = PlanCache(capacity=32, fuzzy=True, fuzzy_threshold=0.7,
                      index_backend="device")
    router = TwoTierRouter(
        cache,
        extract_keyword=lambda r: r["kw"],
        plan_large=lambda r: {"plan": "fresh"},
        plan_small_with_template=lambda r, t: {"plan": "adapted", "tpl": t},
        make_template=lambda r, res: {"tpl_for": r["kw"]},
        async_cachegen=False,
    )
    waves_before = cache._matcher.index._device.batched_updates
    out = router.route_batch([{"kw": f"novel intent {i}"} for i in range(6)])
    assert all(o["plan"] == "fresh" for o in out)
    # the 6 misses distilled into the cache as ONE admission wave
    assert cache._matcher.index._device.batched_updates == waves_before + 1
    out2 = router.route_batch([{"kw": f"novel intent {i}"} for i in range(6)])
    assert all(o["plan"] == "adapted" for o in out2)
    m = router.metrics.snapshot()
    assert m["large_tier_calls"] == 6 and m["small_tier_calls"] == 6
    router.close()


def test_bucketed_telemetry_counts_and_sampled_recall():
    M = _unit_rows(64, seed=8)
    idx = SimilarityIndex(backend="bucketed", initial_capacity=64)
    idx._bucketed._recall_every = 2  # sample aggressively for the test
    idx.add_batch([f"k{i}" for i in range(64)], M)
    idx._bucketed.scan_threshold = 0  # force the probed path
    # query with the stored vectors themselves: identical signatures, so
    # every probe has candidates and the sampled exact re-check must agree
    for r in range(10):
        assert idx.best_match(M[r], threshold=0.99) == f"k{r}"
    snap = idx.telemetry()["bucketed"]
    assert snap["probed_queries"] == 10
    assert snap["recall_checks"] == 5
    assert snap["top1_agreement"] == 1.0
    # every probed query landed in exactly one histogram bucket (bucket
    # "2^0" also holds the zero-candidate queries)
    assert sum(snap["candidate_hist"].values()) == 10


def test_bucketed_recall_sampling_ignores_tombstones():
    """A correct LSH answer must count as agreement even when tombstoned
    zero rows out-score every live row (best live cosine negative)."""
    from repro.index import EmbeddingBank
    from repro.index.bucketed import BucketedIndex

    bank = EmbeddingBank(initial_capacity=8)
    # n_bits=1 + probe_hamming=1 probes both buckets per table, so the
    # candidate set provably contains the single live key
    idx = BucketedIndex(bank, n_bits=1, n_tables=2, scan_threshold=0,
                        recall_sample_every=1)
    v = np.zeros(DIM, np.float32)
    v[0] = 1.0
    for i in range(4):
        w = _unit_rows(1, seed=i)[0]
        idx.on_add(bank.add(f"tomb{i}", w), w)
    idx.on_add(bank.add("live", v), v)
    for i in range(4):
        idx.on_remove(bank.remove(f"tomb{i}"))
    score, slot = idx.best_slot(-v)  # exact live best: cosine -1.0
    assert bank.key_of(slot) == "live" and score == pytest.approx(-1.0)
    snap = idx.telemetry.snapshot()
    assert snap["top1_agreement"] == 1.0  # tombstone argmax would say 0.0

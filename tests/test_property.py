"""Property-based tests (hypothesis) on system invariants.

Skipped cleanly when hypothesis is absent (it is a dev-only dependency —
see requirements-dev.txt); a bare import would error out collection and
take the whole pytest run down with it.
"""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cache import PlanCache
from repro.core.distributed_cache import HashRing
from repro.envs.base import judge
from repro.training.grad_compress import dequantize_int8, quantize_int8

KW = st.text(alphabet="abcdefghij ", min_size=1, max_size=12)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(KW, st.integers()), min_size=1, max_size=60),
       st.integers(min_value=1, max_value=10))
def test_lru_never_exceeds_capacity(ops, cap):
    c = PlanCache(capacity=cap)
    for k, v in ops:
        c.insert(k, v)
        assert len(c) <= cap
    # most recent distinct keys must be resident
    distinct = []
    for k, _ in reversed(ops):
        if k not in distinct:
            distinct.append(k)
    for k in distinct[:cap]:
        assert k in c


@settings(max_examples=40, deadline=None)
@given(st.lists(KW, min_size=1, max_size=40))
def test_cache_lookup_deterministic(keys):
    c1, c2 = PlanCache(capacity=100), PlanCache(capacity=100)
    for i, k in enumerate(keys):
        c1.insert(k, i)
        c2.insert(k, i)
    for k in keys:
        assert c1.lookup(k) == c2.lookup(k)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.text(alphabet="xyz123", min_size=1, max_size=8),
                min_size=5, max_size=60, unique=True),
       st.integers(min_value=2, max_value=6))
def test_ring_assignment_total_and_consistent(keys, n_nodes):
    ring = HashRing(vnodes=32)
    for i in range(n_nodes):
        ring.add(f"n{i}")
    for k in keys:
        owners = ring.nodes_for(k, 2)
        assert 1 <= len(owners) <= min(2, n_nodes)
        assert owners == ring.nodes_for(k, 2)  # deterministic
        assert len(set(owners)) == len(owners)  # distinct replicas


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(min_value=-1e4, max_value=1e4,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=600))
def test_int8_quantization_error_bound(vals):
    x = np.asarray(vals, np.float32)
    payload = quantize_int8(x)
    recon = np.asarray(dequantize_int8(payload))
    # blockwise symmetric int8: |err| <= max|block| / 127 (+eps)
    err = np.abs(recon - x).max() if x.size else 0.0
    bound = np.abs(x).max() / 127.0 + 1e-5 if x.size else 0.0
    assert err <= bound * 1.5 + 1e-6


@settings(max_examples=80, deadline=None)
@given(st.floats(min_value=1e-6, max_value=1e9, allow_nan=False))
def test_judge_accepts_identity_and_unit_slips(gt):
    assert judge(gt, gt)
    assert judge(gt * 1.01, gt)  # within 2%
    assert judge(gt / 100.0, gt)  # percent-vs-fraction slip
    assert not judge(-gt, gt)  # sign errors rejected
    assert not judge(gt * 7.0, gt)  # magnitude errors rejected


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_tokenizer_count_stable(seed):
    from repro.data.tokenizer import HashTokenizer

    t = HashTokenizer()
    text = f"query number {seed} about working capital for company {seed % 97}"
    ids1, ids2 = t.encode(text), t.encode(text)
    assert ids1 == ids2
    assert all(0 <= i < t.vocab_size for i in ids1)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_env_generation_deterministic(seed):
    from repro.envs.workloads import get_env

    env = get_env("tabmwp")
    t1 = env.generate(3, seed=seed)
    t2 = env.generate(3, seed=seed)
    for a, b in zip(t1, t2):
        assert a.query == b.query and a.gt_answer == b.gt_answer
        assert math.isfinite(a.gt_answer)


# -- repro.sim: property tests over random op sequences + fault plans ---------


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=2**16),
       st.sampled_from(["skewed_reuse", "paraphrase_burst", "evict_then_hit",
                        "uniform"]),
       st.sampled_from(["none", "crash_restart", "replica_lag",
                        "hedge_timeout", "mid_wave_evict"]))
def test_sim_random_config_oracle_agreement_and_determinism(seed, scenario,
                                                            fault):
    """Any (seed, scenario, fault-plan) with guards ON must agree with the
    sequential model oracle, and rerun to the identical trace hash."""
    from repro.sim import SimConfig, run_sim

    cfg = SimConfig(seed=seed, scenario=scenario, fault=fault, n_ops=16)
    r = run_sim(cfg)
    assert not r.violations, (cfg, r.violations[:3])
    assert run_sim(cfg).trace_hash == r.trace_hash


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=2**16))
def test_sim_failing_seed_replays_to_identical_trace(seed):
    """A run that DOES violate (guard ablated) must still be a pure
    function of its config: rerunning the failing seed reproduces the
    identical trace hash and the identical violation list."""
    from repro.sim import SimConfig, run_sim

    cfg = SimConfig(seed=seed, fault="crash_restart",
                    ablate=("crash_fallthrough",), n_ops=24)
    a, b = run_sim(cfg), run_sim(cfg)
    assert a.trace_hash == b.trace_hash
    assert [(v.step, v.oracle) for v in a.violations] == \
           [(v.step, v.oracle) for v in b.violations]


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["insert", "lookup", "remove"]),
                          KW, st.integers()),
                min_size=1, max_size=80),
       st.integers(min_value=1, max_value=8))
def test_plan_cache_random_ops_agree_with_dict_model(ops, cap):
    """PlanCache vs the simplest possible sequential model: a dict plus an
    LRU recency list (the single-store analogue of repro.sim's ModelStore)."""
    c = PlanCache(capacity=cap)
    model, recency = {}, []

    def touch(k):
        if k in recency:
            recency.remove(k)
        recency.append(k)

    for op, k, v in ops:
        if op == "insert":
            c.insert(k, v)
            model[k] = v
            touch(k)
            while len(model) > cap:
                victim = recency.pop(0)
                del model[victim]
        elif op == "lookup":
            got = c.lookup(k)
            want = model.get(k)
            assert got == want, (op, k, got, want)
            if want is not None:
                touch(k)
        else:
            assert c.remove(k) == (k in model)
            if k in model:
                del model[k]
                recency.remove(k)
    assert sorted(c.keys()) == sorted(model)


_CHURN_EVENTS = st.lists(
    st.tuples(
        st.sampled_from(["join", "drain", "crash", "restart", "insert",
                         "lookup"]),
        st.integers(min_value=0, max_value=9),
    ),
    min_size=3, max_size=16,
)


@settings(max_examples=20, deadline=None)
@given(_CHURN_EVENTS, st.integers(min_value=0, max_value=2**16))
def test_ring_change_sequences_agree_with_model(events, seed):
    """Churn-vs-oracle property: ANY interleaving of joins, graceful
    drains, crashes, restarts and data waves must keep DistributedPlanCache
    in agreement with the ring-change-mirroring ModelStore — every lookup
    matches the model's prediction, and once every node is reachable again
    the visible key set matches exactly."""
    import random as _random

    from repro.core.distributed_cache import DistributedPlanCache, ShardUnavailable
    from repro.sim.oracle import ModelStore, make_value

    class _Interceptor:
        def __init__(self):
            self.crashed = set()

        def call(self, node, op, fn):
            if node in self.crashed:
                raise ShardUnavailable(node)
            return fn()

    rng = _random.Random(seed)
    ic = _Interceptor()
    dc = DistributedPlanCache(3, replication=2, capacity_per_node=256,
                              interceptor=ic)
    model = ModelStore(replication=2, capacity_per_node=256)
    for name in list(dc.shards):
        model.add_node(name)

    kws = [f"kw-{i}" for i in range(24)]
    versions = {}
    joined = 0

    def check_lookups(sample):
        for kw in sample:
            want, strict = model.lookup(kw)
            got = dc.lookup(kw)
            assert not strict or got == want, (kw, got, want)

    for kind, pick in events:
        members = list(dc.shards)
        if kind == "join" and len(members) < 8:
            name = f"cache-join-{joined}"
            joined += 1
            dc.add_node(name)
            model.join(name)
        elif kind == "drain" and len(members) > 2:
            name = members[pick % len(members)]
            dc.remove_node(name)
            model.drain(name)
            ic.crashed.discard(name)
        elif kind == "crash":
            live = [n for n in members if n not in ic.crashed]
            if live:
                name = live[pick % len(live)]
                ic.crashed.add(name)
                model.crash(name)
        elif kind == "restart":
            down = sorted(ic.crashed)
            if down:
                name = down[pick % len(down)]
                ic.crashed.discard(name)
                dc.restart_node(name, recover=True)
                model.restart(name, recover=True)
        elif kind == "insert":
            wave = rng.sample(kws, 4)
            items = []
            for kw in wave:
                versions[kw] = versions.get(kw, 0) + 1
                items.append((kw, make_value(kw, versions[kw])))
            dc.insert_batch(items)
            model.insert_wave(items)
        else:  # lookup
            check_lookups(rng.sample(kws, 6))
        check_lookups(rng.sample(kws, 2))

    # quiesce: restart everything still crashed, then the full state and
    # the control-plane view must agree exactly
    for name in sorted(ic.crashed):
        ic.crashed.discard(name)
        dc.restart_node(name, recover=True)
        model.restart(name, recover=True)
    check_lookups(kws)
    assert dc.keys() == model.visible_keys() == model.keys()


# -- repro.memory.tiered: cold-tier round-trip + compaction properties --------

_STEP = st.tuples(
    st.sampled_from(["message", "output", "answer"]),
    st.text(alphabet="abcdef 0123", min_size=0, max_size=240),
    st.one_of(st.none(), st.dictionaries(
        st.sampled_from(["tool", "arg"]),
        st.text(alphabet="xyz", min_size=1, max_size=6), max_size=2)),
)


def _template_from(draws):
    from repro.core.template import PlanStep, PlanTemplate

    return PlanTemplate(
        "drawn keyword",
        [PlanStep(k, c, op) for k, c, op in draws],
        source_task="drawn task",
    )


@settings(max_examples=40, deadline=None)
@given(st.lists(_STEP, min_size=1, max_size=12))
def test_spill_promote_roundtrip_preserves_template_semantics(draws):
    """Through the on-disk segment encoding and back: with a non-binding
    compaction budget, spill -> promote is the identity on templates."""
    import tempfile

    from repro.memory import ColdTier

    tpl = _template_from(draws)
    with tempfile.TemporaryDirectory() as d:
        ct = ColdTier(d, budget_tokens=10**9)
        ct.spill([("k", tpl, "ctx", None, 1.0)])
        back = ct.take(["k"])[0].value
    assert [s.to_json() for s in back.steps] == [s.to_json() for s in tpl.steps]
    assert (back.keyword, back.source_task, back.uses) == (
        tpl.keyword, tpl.source_task, tpl.uses)
    assert back.size_tokens() == tpl.size_tokens()


@settings(max_examples=60, deadline=None)
@given(st.lists(_STEP, min_size=1, max_size=12),
       st.integers(min_value=1, max_value=400))
def test_compaction_idempotent_and_monotone(draws, budget):
    """compact_template never grows size_tokens, keeps the slotted
    skeleton, and is idempotent at any budget."""
    from repro.memory import compact_template

    tpl = _template_from(draws)
    once, saved = compact_template(tpl, budget_tokens=budget)
    assert saved >= 0
    assert once.size_tokens() == tpl.size_tokens() - saved
    assert once.size_tokens() <= tpl.size_tokens()
    # the slotted skeleton (message ops) survives every pass
    assert [s.op for s in once.message_steps()] == \
        [s.op for s in tpl.message_steps()]
    twice, saved2 = compact_template(once, budget_tokens=budget)
    assert saved2 == 0
    assert [s.to_json() for s in twice.steps] == \
        [s.to_json() for s in once.steps]


@settings(max_examples=25, deadline=None)
@given(st.dictionaries(st.sampled_from(["company", "year", "student"]),
                       st.text(alphabet="ABCdef123", min_size=2, max_size=8),
                       min_size=1, max_size=3))
def test_generalize_then_instantiate_roundtrip(slots):
    from repro.core.template import PlanStep, generalize, instantiate

    content = "Retrieve data for " + " ".join(str(v) for v in slots.values())
    steps = [PlanStep("message", content, {"scope": dict(slots)})]
    gen = generalize(steps, slots)
    inst = instantiate(gen[0].op, slots)
    assert inst["scope"] == slots  # roundtrip restores the original bindings

"""Config registry + assignment-rule tests."""

import pytest

from repro.configs import registry
from repro.configs.base import SHAPES, supports_shape


def test_all_archs_registered():
    assert len(registry.ARCH_NAMES) == 10


@pytest.mark.parametrize("arch", registry.ARCH_NAMES)
def test_config_loads(arch):
    cfg = registry.get(arch)
    assert cfg.name == arch
    assert cfg.param_count() > 0
    assert registry.get_sharding(arch).tp_axis in ("model", "")


def test_param_counts_match_public_figures():
    # total params within 20% of the advertised size class
    expect = {
        "qwen3-4b": 4.4e9, "olmo-1b": 1.2e9, "nemotron-4-15b": 15.6e9,
        "qwen2.5-3b": 3.4e9, "rwkv6-3b": 3.1e9, "qwen2-vl-7b": 7.6e9,
        "kimi-k2-1t-a32b": 1.04e12, "granite-moe-1b-a400m": 1.3e9,
        "zamba2-2.7b": 2.4e9, "whisper-tiny": 6e7,
    }
    for arch, n in expect.items():
        got = registry.get(arch).param_count()
        assert abs(got - n) / n < 0.2, (arch, got, n)


def test_kimi_active_params():
    cfg = registry.get("kimi-k2-1t-a32b")
    assert 28e9 < cfg.active_param_count() < 36e9  # ~32B active


def test_long_500k_rules():
    # sub-quadratic only
    for arch in registry.ARCH_NAMES:
        cfg = registry.get(arch)
        ok = supports_shape(cfg, SHAPES["long_500k"])
        assert ok == (cfg.family in ("ssm", "hybrid")), arch


def test_cell_count():
    # 10 archs x 4 shapes - 8 skipped long_500k = 32
    assert len(registry.all_cells()) == 32


@pytest.mark.parametrize("arch", registry.ARCH_NAMES)
def test_smoke_reduction_preserves_family(arch):
    full = registry.get(arch)
    smoke = registry.get_smoke(arch)
    assert smoke.family == full.family
    assert smoke.param_count() < full.param_count() / 50
    assert (smoke.moe is None) == (full.moe is None)
    assert (smoke.ssm is None) == (full.ssm is None)
    assert (smoke.encoder is None) == (full.encoder is None)

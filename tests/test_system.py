"""End-to-end system behavior: the paper's headline claims, measured.

These run the full APC pipeline (keyword -> cache -> two-tier planning ->
actor -> judge) over executable envs and assert the DIRECTION and rough
magnitude of every paper claim:

  * APC cuts cost vs accuracy-optimal while keeping most of its accuracy;
  * semantic caching degrades badly on hits (false-positive reuse);
  * full-history caching is worse than APC on accuracy;
  * cache-hit accuracy ~ cache-miss accuracy for APC (Fig 5);
  * overhead (keyword extraction + cache generation) is ~1% of cost;
  * cold start warms up (hit rate rises across the stream).
"""

import pytest

from repro.core.harness import METHODS, run_workload

N = 150
ENV = "financebench"


@pytest.fixture(scope="module")
def results():
    return {m: run_workload(ENV, m, N, keep_records=True) for m in METHODS}


def test_apc_cost_reduction(results):
    apc, acc_opt = results["apc"], results["accuracy_optimal"]
    assert apc.cost < 0.70 * acc_opt.cost  # paper: ~50% reduction


def test_apc_maintains_accuracy(results):
    apc, acc_opt = results["apc"], results["accuracy_optimal"]
    assert apc.accuracy > 0.90 * acc_opt.accuracy  # paper: 96.6% kept


def test_apc_latency_reduction(results):
    apc, acc_opt = results["apc"], results["accuracy_optimal"]
    assert apc.latency_s < 0.90 * acc_opt.latency_s  # paper: ~27%


def test_cost_optimal_is_cheap_but_inaccurate(results):
    co, ao = results["cost_optimal"], results["accuracy_optimal"]
    assert co.cost < 0.10 * ao.cost
    assert co.accuracy < 0.75 * ao.accuracy


def test_semantic_caching_degrades_on_hits(results):
    sem = results["semantic"]
    assert sem.hit_rate > 0.2  # it does hit...
    assert sem.hit_accuracy < 0.3  # ...but hits are mostly false positives
    assert sem.accuracy < results["apc"].accuracy


def test_full_history_worse_than_apc(results):
    fh, apc = results["full_history"], results["apc"]
    assert fh.accuracy < apc.accuracy
    assert fh.hit_accuracy < apc.hit_accuracy


def test_apc_hit_accuracy_stable(results):
    apc = results["apc"]
    assert apc.hit_accuracy is not None and apc.miss_accuracy is not None
    # Fig 5c: no cliff between hit and miss accuracy
    assert apc.hit_accuracy > apc.miss_accuracy - 0.15


def test_overhead_is_small(results):
    apc = results["apc"]
    bd = apc.breakdown
    overhead = sum(
        bd.get(r, {}).get("cost", 0.0)
        for r in ("keyword_extractor", "cache_generator")
    )
    assert overhead / apc.cost < 0.05  # paper: ~1%


def test_cold_start_warms_up(results):
    recs = results["apc"].records
    first = recs[: N // 3]
    last = recs[-N // 3 :]
    hr = lambda rs: sum(r.hit for r in rs) / len(rs)
    assert hr(last) > hr(first) + 0.15


def test_determinism():
    a = run_workload("tabmwp", "apc", 40, seed=3)
    b = run_workload("tabmwp", "apc", 40, seed=3)
    assert a.accuracy == b.accuracy and a.cost == b.cost


@pytest.mark.parametrize("env", ["tabmwp", "qasper", "aime", "gaia"])
def test_apc_beats_accuracy_optimal_cost_everywhere(env):
    n = 60
    apc = run_workload(env, "apc", n)
    ao = run_workload(env, "accuracy_optimal", n)
    assert apc.cost < ao.cost
    assert apc.accuracy > 0.8 * ao.accuracy


def test_gaia_low_initial_hit_rate():
    """GAIA's heterogeneous tasks rarely share keywords (paper §4.2)."""
    gaia = run_workload("gaia", "apc", 80)
    fin = run_workload(ENV, "apc", 80)
    assert gaia.hit_rate < fin.hit_rate


def test_cache_capacity_effect():
    """Table 4: larger caches -> higher hit rate, lower cost."""
    from repro.core.agent_loop import AgentConfig

    small = run_workload(ENV, "apc", 120, agent_cfg=AgentConfig(cache_capacity=5))
    large = run_workload(ENV, "apc", 120, agent_cfg=AgentConfig(cache_capacity=100))
    assert large.hit_rate > small.hit_rate
    assert large.cost < small.cost


def test_fuzzy_matching_tradeoff():
    """Table 6: fuzzy raises hit rate without raising cost."""
    from repro.core.agent_loop import AgentConfig

    exact = run_workload(ENV, "apc", 120)
    fuzzy = run_workload(
        ENV, "apc", 120,
        agent_cfg=AgentConfig(fuzzy=True, fuzzy_threshold=0.55),
    )
    assert fuzzy.hit_rate >= exact.hit_rate
    assert fuzzy.cost <= exact.cost * 1.02

"""Second agent architecture (paper §4.2: Open Deep Research on GAIA) +
cache pre-warming (paper §4.5)."""

from repro.core.deep_research import run_deep_research
from repro.core.harness import run_workload


def test_deep_research_apc_cuts_cost_on_gaia():
    """Paper Table 1: GAIA $69.02 -> $16.27 (-76%) with ~no accuracy loss.
    Direction + accuracy-preservation asserted (cost scale differs: our
    synthetic GAIA has shorter trajectories)."""
    base = run_deep_research("gaia", 120, use_apc=False)
    apc = run_deep_research("gaia", 120, use_apc=True)
    assert apc["cost"] < base["cost"]
    assert apc["accuracy"] > base["accuracy"] - 0.06
    assert apc["hit_rate"] > 0.2  # re-planning skeletons DO recur
    assert base["hit_rate"] == 0.0


def test_deep_research_works_on_recurring_workloads_too():
    r = run_deep_research("tabmwp", 80, use_apc=True)
    assert r["hit_rate"] > 0.4  # dense intent space -> high reuse
    assert r["accuracy"] > 0.6


def test_prewarm_eliminates_cold_start():
    """Paper §4.5: pre-populating the cache with offline samples."""
    from repro.configs.apc_minion import DEFAULT
    from repro.core.agent_loop import AgentConfig, PlanActAgent
    from repro.core.backends import SimulatedBackend
    from repro.core.cost_model import CostLedger
    from repro.envs.workloads import get_env

    env = get_env("tabmwp")
    offline = env.generate(60, seed=99)  # offline sample set
    online = env.generate(40, seed=1)

    def make_agent():
        return PlanActAgent(
            SimulatedBackend(seed=0),
            CostLedger(pricing_map=dict(DEFAULT.pricing)),
            AgentConfig(method="apc"),
        )

    cold = make_agent()
    cold_recs = [cold.run_task(t) for t in online]
    warm = make_agent()
    inserted = warm.prewarm(offline)
    assert inserted > 10
    warm_recs = [warm.run_task(t) for t in online]
    hr = lambda rs: sum(r.hit for r in rs) / len(rs)
    assert hr(warm_recs) > hr(cold_recs) + 0.25  # cold start mitigated
    acc = sum(r.correct for r in warm_recs) / len(warm_recs)
    assert acc > 0.6

"""HLO cost-model correctness: the parser must recover scan-multiplied
FLOPs/collectives that cost_analysis() undercounts."""

import jax
import jax.numpy as jnp

from repro.launch.roofline import HloCostModel, shape_bytes, shape_dims


def _parse(fn, *args) -> HloCostModel:
    compiled = jax.jit(fn).lower(*args).compile()
    return HloCostModel(compiled.as_text())


def test_shape_parsing():
    assert shape_bytes("f32[16,2048]{1,0}") == 16 * 2048 * 4
    assert shape_bytes("bf16[8]") == 16
    assert shape_bytes("(f32[2,2], s32[])") == 16 + 4
    assert shape_dims("f32[3,4,5]{2,1,0}") == [3, 4, 5]
    assert shape_bytes("pred[]") == 1


def test_single_matmul_flops():
    x = jnp.zeros((128, 256), jnp.float32)
    w = jnp.zeros((256, 64), jnp.float32)
    m = _parse(lambda a, b: a @ b, x, w)
    cost = m.entry_cost()
    assert abs(cost.flops - 2 * 128 * 256 * 64) / (2 * 128 * 256 * 64) < 0.01


def test_scan_flops_multiplied_by_trip_count():
    """THE key property: scan body x trip count (cost_analysis counts once)."""
    L = 7
    x = jnp.zeros((64, 64), jnp.float32)
    ws = jnp.zeros((L, 64, 64), jnp.float32)

    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    compiled = jax.jit(f).lower(x, ws).compile()
    raw = compiled.cost_analysis()
    if isinstance(raw, list):  # jax<=0.4 returns one entry per program
        raw = raw[0]
    raw = raw["flops"]
    parsed = HloCostModel(compiled.as_text()).entry_cost().flops
    expected = L * 2 * 64**3
    assert abs(parsed - expected) / expected < 0.05, (parsed, expected)
    assert raw < expected / 2  # documents the undercount we correct


def test_nested_scan_multiplies_both_levels():
    x = jnp.zeros((32, 32), jnp.float32)
    ws = jnp.zeros((3, 4, 32, 32), jnp.float32)

    def f(x, ws):
        def outer(c, wrow):
            def inner(c2, w):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, wrow)
            return c, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    parsed = _parse(f, x, ws).entry_cost().flops
    expected = 12 * 2 * 32**3
    assert abs(parsed - expected) / expected < 0.1, parsed


def test_unrolled_matches_scan_accounting():
    x = jnp.zeros((64, 64), jnp.float32)
    ws = jnp.zeros((5, 64, 64), jnp.float32)

    def f_unroll(x, ws):
        for i in range(5):
            x = x @ ws[i]
        return x

    def f_scan(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]

    a = _parse(f_unroll, x, ws).entry_cost().flops
    b = _parse(f_scan, x, ws).entry_cost().flops
    assert abs(a - b) / a < 0.05


def test_collective_bytes_from_sharded_fn():
    # subprocess builds its mesh through repro.distributed.mesh_compat, so
    # it runs on jax 0.4.37 as well as the jax>=0.6 AxisType surface
    import os
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent(
        """
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.mesh_compat import make_mesh
        from repro.launch.roofline import HloCostModel
        mesh = make_mesh((8,), ('d',))
        sh = NamedSharding(mesh, P('d', None))
        rep = NamedSharding(mesh, P())
        x = jax.ShapeDtypeStruct((1024, 64), jnp.float32)
        f = jax.jit(lambda x: x.sum(0), in_shardings=(sh,), out_shardings=rep)
        compiled = f.lower(x).compile()
        c = HloCostModel(compiled.as_text()).entry_cost()
        assert c.total_coll_bytes > 0, c.coll_bytes
        assert 'all-reduce' in c.coll_bytes, c.coll_bytes
        print('OK', c.coll_bytes)
        """
    )
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=240)
    assert out.returncode == 0, out.stderr[-2000:]


def test_analytic_memory_model_sane():
    from repro.launch.roofline import analytic_memory_bytes

    m = analytic_memory_bytes("qwen3-4b", "decode_32k", {"data": 16, "model": 16})
    # decode is dominated by weight + KV reads; both components present
    assert m["weights"] > 0 and m["kv_read"] > 0
    # weights per device ~ P*2/tp
    assert abs(m["weights"] - 4.41e9 * 2 / 16) / m["weights"] < 0.2

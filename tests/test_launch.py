"""Launch layer: input specs for all cells, serve pipeline on JAX engines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.launch.specs import input_specs
from repro.models import lm


@pytest.mark.parametrize("arch,shape_name", registry.all_cells())
def test_input_specs_all_cells(arch, shape_name):
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    batch, cache = input_specs(cfg, shape)
    if shape.kind == "decode":
        assert batch["tokens"].shape == (shape.global_batch, 1)
        assert cache is not None and "length" in cache
        if cfg.family in ("dense", "moe", "vlm"):
            L, B, M, H, hd = cache["kv_k"].shape
            assert (L, B, H, hd) == (
                cfg.num_layers, shape.global_batch, cfg.num_kv_heads, cfg.head_dim
            )
            assert M >= shape.seq_len
        if cfg.family in ("ssm", "hybrid"):
            assert "ssm_state" in cache
    else:
        assert cache is None
        if cfg.family == "vlm":
            assert batch["embeds"].shape == (
                shape.global_batch, shape.seq_len, cfg.d_model
            )
            assert batch["positions"].shape == (3, shape.global_batch, shape.seq_len)
        elif cfg.family == "audio":
            assert batch["frames"].shape[1] == cfg.encoder.num_frames
        else:
            assert batch["tokens"].shape == (shape.global_batch, shape.seq_len)
        if shape.kind == "train":
            assert "labels" in batch


@pytest.mark.parametrize("arch", ["rwkv6-3b", "zamba2-2.7b"])
def test_engine_generates_with_state_caches(arch, rng_key):
    """SSM/hybrid archs generate through the engine (state carried, no KV)."""
    from repro.serving.engine import Engine

    cfg = registry.get_smoke(arch)
    params = lm.init_params(cfg, rng_key)
    eng = Engine(cfg, params, max_len=48)
    toks = np.random.RandomState(0).randint(3, 400, (2, 10)).astype(np.int32)
    out = eng.generate(toks, max_new=6)
    assert out.shape == (2, 6)
    assert (out >= 0).all()


def test_jax_backend_serves_apc_end_to_end(rng_key):
    from repro.configs.apc_minion import DEFAULT
    from repro.core.agent_loop import AgentConfig, PlanActAgent
    from repro.core.cost_model import CostLedger
    from repro.envs.workloads import get_env
    from repro.serving.engine import Engine
    from repro.serving.jax_backend import JaxBackend

    cfg = registry.get_smoke("olmo-1b")
    params = lm.init_params(cfg, rng_key)
    eng = Engine(cfg, params, max_len=96)
    engines = {r: eng for r in
               ("large_planner", "small_planner", "actor", "keyword_extractor")}
    backend = JaxBackend(engines, seed=0, max_exec_tokens=4)
    ledger = CostLedger(pricing_map=dict(DEFAULT.pricing))
    agent = PlanActAgent(backend, ledger, AgentConfig(method="apc"))
    tasks = get_env("tabmwp").generate(6, seed=0)
    recs = [agent.run_task(t) for t in tasks]
    assert len(recs) == 6
    assert eng.stats.decode_tokens > 0  # real data-plane tokens served
    assert ledger.total_cost() > 0

"""Fixture: a pragma that legitimately suppresses a finding.

``analyze_paths`` must return no determinism finding AND no
pragma-hygiene finding for this file.
"""

import time


def stamp():
    # analysis: clock-ok(fixture demonstrating suppression; not sim code)
    return time.time()

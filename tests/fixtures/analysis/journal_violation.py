"""Golden VIOLATING fixture for the journal-discipline checker.

Three expected findings: a discarded-undo workspace write, an undo
parked in a local instead of journaled at the call site, and an
attribute-chained workspace delete outside any journal entry.
"""


def run(ws, task, journal):
    step = journal.begin_step("round-0")
    ws.write("r0/out", 1)  # discarded undo: the rollback path cannot see it
    undo = ws.write("r0/tmp", 2)  # parked undo: not provably journaled
    task.workspace.delete("r0/tmp")  # unjournaled delete via attribute chain
    step.applied(undo)

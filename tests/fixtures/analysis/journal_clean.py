"""Golden CLEAN fixture for the journal-discipline checker.

Exercises what it must NOT flag: the blessed direct idiom (positional
and attribute-chained receivers), non-mutating workspace reads, writers
on non-workspace receivers (file-likes), and a pragma'd manual
compensation site.
"""


def run(ws, task, step, buf):
    step.applied(ws.write("r0/out", 1))  # the one blessed idiom
    step.applied(task.workspace.delete("r0/tmp"))  # attribute-chained receiver
    ws.read("r0/out")
    ws.keys()
    buf.write(b"bytes")  # file-like writer, not env state
    ws.write("r0/manual", 2)  # analysis: journal-ok(fixture compensates by hand)

"""Golden CLEAN fixture for the thread-hygiene checker.

The dispositions the checker must accept: shutdown reachable from
another method (through a conditional-expression binding, the
``TwoTierRouter._pool`` shape), a context-managed pool, daemon=True,
an assigned ``.daemon = True``, and an explicit join.
"""

import threading
from concurrent.futures import ThreadPoolExecutor


class Pool:
    def __init__(self, workers, enabled):
        self._pool = ThreadPoolExecutor(max_workers=workers) if enabled else None

    def close(self):
        if self._pool is not None:
            self._pool.shutdown(wait=True)


def scoped(tasks):
    with ThreadPoolExecutor(max_workers=2) as pool:
        return [pool.submit(t).result() for t in tasks]


def daemonized(fn):
    t = threading.Thread(target=fn, daemon=True)
    t.start()
    return t


def daemon_assigned(fn):
    t1 = threading.Thread(target=fn)
    t1.daemon = True
    t1.start()
    return t1


def joined(fn):
    t2 = threading.Thread(target=fn)
    t2.start()
    t2.join()

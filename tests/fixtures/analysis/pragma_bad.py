"""Fixture: malformed pragmas — empty reason, unknown kind.

Both are pragma-hygiene findings.
"""

import time


def stamp():
    # analysis: clock-ok()
    return time.time()


def other():
    # analysis: wibble-ok(no checker uses this kind)
    return 1

"""Golden CLEAN fixture for the jit-safety checker.

The safe idioms: output-ref subscript writes in a pallas kernel
(params are writable), the rebind-from-result donation pattern, and the
forwarding-helper indirection (``_donated(fn, *args)`` /
``functools.partial``) from ``index/device.py``.
"""

import functools

import jax


@functools.partial(jax.jit, donate_argnums=(0,))
def scatter(arena, idx, val):
    return arena.at[idx].set(val)


@functools.partial(jax.jit, static_argnames=("new_cap",), donate_argnums=(0,))
def grow(arena, *, new_cap):
    return arena


def _donated(fn, *args):
    return fn(*args)


def kernel(x_ref, o_ref):
    acc = x_ref[...] * 2
    o_ref[...] = acc  # param subscript write: the pallas ref-write idiom


def run_kernel(pl, x):
    return pl.pallas_call(kernel, out_shape=x)(x)


def direct_rebind(arena, idx, val):
    arena = scatter(arena, idx, val)
    return arena.sum()


class Bank:
    def __init__(self, arena):
        self._arena = arena

    def set_row(self, idx, val):
        self._arena = _donated(scatter, self._arena, idx, val)
        return self._arena.shape

    def grow_to(self, new_cap):
        self._arena = _donated(
            functools.partial(grow, new_cap=new_cap), self._arena
        )

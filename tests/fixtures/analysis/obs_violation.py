"""Golden VIOLATING fixture for the obs-names checker.

Three expected findings: literal names handed to a counter, a span,
and a span event.
"""


def instrument(registry, tracer):
    c = registry.counter("router.requests")
    with tracer.span("router.route") as sp:
        sp.event("cache.attribution", hit=True)
    return c

"""Fixture: a stale pragma — the line it guards no longer violates.

``analyze_paths`` must flag it as pragma-hygiene so suppressions
cannot silently rot.
"""

import time


def seam(clock=None):
    # analysis: clock-ok(stale: the call below became a seam reference)
    return clock if clock is not None else time.time

"""Golden VIOLATING fixture for the thread-hygiene checker.

Three expected findings: a bound executor with no reachable shutdown,
a bound thread with no join/daemon disposition, and an unbound
construction.
"""

import threading
from concurrent.futures import ThreadPoolExecutor


def leak_pool(tasks):
    pool = ThreadPoolExecutor(max_workers=2)
    return [pool.submit(t) for t in tasks]


def leak_thread(fn):
    t = threading.Thread(target=fn)
    t.start()
    return t


def unbound(fn):
    return ThreadPoolExecutor(max_workers=1).submit(fn)

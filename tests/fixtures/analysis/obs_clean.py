"""Golden CLEAN fixture for the obs-names checker.

All instrumentation names flow through the ``repro.obs.names`` catalog.
"""

from repro.obs import names as _names


def instrument(registry, tracer):
    c = registry.counter(_names.ROUTER_REQUESTS)
    h = registry.histogram(_names.ROUTER_LOOKUP_LATENCY)
    with tracer.span(_names.SPAN_ROUTE) as sp:
        sp.event(_names.EVENT_ATTRIBUTION, hit=True)
    return c, h

"""Golden VIOLATING fixture for the jit-safety checker.

Expected findings: a print and a captured-state write inside a jitted
body, a captured-state write inside a pallas kernel, and a
read-after-donation at a caller site.
"""

import functools

import jax

STATE = {}


@functools.partial(jax.jit, donate_argnums=(0,))
def scatter(arena, idx, val):
    return arena.at[idx].set(val)


@jax.jit
def impure(x):
    print("tracing")        # side effect under trace
    STATE["calls"] = 1      # captured-state mutation under trace
    return x * 2


def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2
    STATE["kernel_ran"] = True  # captured-state mutation in a kernel


def run_kernel(pl, x):
    return pl.pallas_call(kernel, out_shape=x)(x)


def read_after_donation(arena, idx, val):
    out = scatter(arena, idx, val)
    return out.sum() + arena.sum()  # arena's buffer was donated above

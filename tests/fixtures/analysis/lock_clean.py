"""Golden CLEAN fixture for the lock-discipline checker.

Exercises the patterns the checker must NOT flag: construction-time
writes, lexically-held writes, a private helper whose only call sites
hold the lock (the ``EmbeddingBank._grow`` shape), a nested function
defined inside the locked region (the ``PlanCache.insert_batch``
shape), and a dataclass-field lock.
"""

import threading
from dataclasses import dataclass, field


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self.items = {}

    def bump(self):
        with self._lock:
            self.count += 1
            self._grow()

    def _grow(self):
        # only ever called from bump, under the lock
        self.items["cap"] = self.count * 2

    def insert(self):
        with self._lock:
            def evict():
                self.count -= 1  # nested def inherits the held state
            evict()


@dataclass
class FieldLocked:
    total: int = 0
    lock: threading.Lock = field(default_factory=threading.Lock)

    def add(self, n):
        with self.lock:
            self.total += n

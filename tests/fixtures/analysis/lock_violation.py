"""Golden VIOLATING fixture for the lock-discipline checker.

Three expected findings: the unheld write in ``bump``, the unheld write
in ``_bump_unlocked`` (reachable from a public method without the
lock), and ``caller``'s unheld call site into it.
"""

import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def bump(self):
        self.count += 1  # unheld write in a lock-owning class

    def caller(self):
        self._bump_unlocked()  # unheld call to a lock-requiring helper

    def _bump_unlocked(self):
        self.count += 1

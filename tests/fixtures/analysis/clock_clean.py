"""Golden CLEAN fixture for the determinism checker.

The injectable clock seam (a bare wall-clock REFERENCE stored as the
default, called through the attribute) and seeded RNG constructions.
"""

import random
import time

import numpy as np


class Clocked:
    def __init__(self, clock=None):
        # reference, not a call: repro.sim rebinds this to a VirtualClock
        self._clock = clock if clock is not None else time.time

    def now(self):
        return self._clock()


def make_rng(seed):
    return random.Random(seed)


def make_np_rng(seed):
    return np.random.default_rng(seed)

"""Golden VIOLATING fixture for the determinism checker.

Four expected findings: a wall-clock call, a global-RNG draw, an
unseeded generator construction, and a global numpy draw.
"""

import random
import time

import numpy as np


def stamp():
    return time.time()  # wall-clock CALL, not the seam reference


def jitter():
    return random.random()  # process-global RNG draw


def make_rng():
    return np.random.default_rng()  # unseeded generator


def shuffle_global(xs):
    np.random.shuffle(xs)  # numpy process-global RNG

"""APC control-plane unit tests: cache, templates, keyword, fuzzy,
distributed cache, speculative prefetch."""

import numpy as np
import pytest

from repro.core import fuzzy
from repro.sim.clock import VirtualClock
from repro.core.cache import PlanCache
from repro.core.distributed_cache import DistributedPlanCache, HashRing
from repro.core.speculative import KeywordPredictor, SpeculativePrefetcher
from repro.core.template import (
    ExecutionLog,
    PlanTemplate,
    generalize,
    instantiate,
    make_template,
    rule_filter,
)


# -- PlanCache ---------------------------------------------------------------


def test_lru_eviction_order():
    c = PlanCache(capacity=3)
    for k in "abc":
        c.insert(k, k)
    c.lookup("a")  # touch a -> b is now LRU
    c.insert("d", "d")
    assert "b" not in c and "a" in c and len(c) == 3
    assert c.stats.evictions == 1


def test_exact_matching_no_false_positives():
    c = PlanCache(capacity=10)
    c.insert("working capital ratio", 1)
    assert c.lookup("working capital ratios") is None  # near-miss must miss
    assert c.lookup("working capital ratio") == 1


def test_fuzzy_matching_hits_near_keywords():
    c = PlanCache(capacity=10, fuzzy=True, fuzzy_threshold=0.7)
    c.insert("working capital ratio", 1)
    assert c.lookup("working capital ratio calculation") == 1
    assert c.lookup("orbital mechanics of jupiter") is None


def test_cache_serialization_roundtrip():
    c = PlanCache(capacity=5)
    for i in range(4):
        c.insert(f"k{i}", {"v": i})
    c2 = PlanCache.from_state(c.to_state())
    assert sorted(c2.keys()) == sorted(c.keys())
    assert c2.lookup("k2") == {"v": 2}


def test_ttl_expiry():
    # injectable clock: expiry is driven explicitly, not by hoping the
    # wall clock ticked between insert and lookup
    clock = VirtualClock()
    c = PlanCache(capacity=5, ttl_s=10.0, clock=clock)
    c.insert("k", 1)
    assert c.lookup("k") == 1  # fresh
    clock.advance(10.1)
    assert c.lookup("k") is None  # stale after the TTL elapses


# -- templates ---------------------------------------------------------------


def _mklog():
    log = ExecutionLog(task_query="What is FY2019 working capital ratio for Costco?")
    log.append(
        {
            "message": "Please provide total_current_assets, total_current_liabilities "
            "for Costco. Here is a very long chain of thought that should be dropped.",
            "op": {"retrieve": ["total_current_assets", "total_current_liabilities"],
                   "scope": {"company": "Costco", "year": "2019"}},
        },
        {"values": {"total_current_assets": 23485.0, "total_current_liabilities": 23237.0}},
    )
    log.final_answer = {
        "answer_text": "The answer is 1.01.",
        "op": {"compute": "a / b", "value": 1.01},
    }
    return log


def test_rule_filter_drops_verbosity():
    steps = rule_filter(_mklog())
    kinds = [s.kind for s in steps]
    assert kinds == ["message", "output", "answer"]
    assert "chain of thought" not in steps[0].content


def test_generalize_strips_slots_and_numbers():
    tpl = make_template(_mklog(), "working capital ratio",
                        {"company": "Costco", "year": "2019"})
    text = " ".join(s.content for s in tpl.steps) + str(
        [s.op for s in tpl.steps]
    )
    assert "Costco" not in text
    assert "{company}" in text
    assert tpl.answer_step().op["compute"] == "a / b"


def test_instantiate_fills_new_slots():
    tpl = make_template(_mklog(), "working capital ratio",
                        {"company": "Costco", "year": "2019"})
    step = tpl.message_steps()[0]
    op = instantiate(step.op, {"company": "Best Buy", "year": "2021"})
    assert op["scope"]["company"] == "Best Buy"
    assert "Costco" not in str(op)


def test_generalize_miss_slot_leaks():
    """A generalization miss (lightweight-LM failure mode) leaves the slot
    baked in — the paper's bad-template hazard."""
    tpl = make_template(_mklog(), "working capital ratio",
                        {"company": "Costco", "year": "2019"},
                        miss_slots=["company"])
    assert "Costco" in str([s.op for s in tpl.steps]) + " ".join(
        s.content for s in tpl.steps
    )


# -- fuzzy embedding ----------------------------------------------------------


def test_embed_deterministic_and_normalized():
    e1, e2 = fuzzy.embed("mean calculation"), fuzzy.embed("mean calculation")
    assert np.allclose(e1, e2)
    assert abs(np.linalg.norm(e1) - 1.0) < 1e-5


def test_similarity_orders_sensibly():
    close = fuzzy.similarity("working capital ratio", "working capital ratio analysis")
    far = fuzzy.similarity("working capital ratio", "video dialogue transcripts")
    assert close > far + 0.2


# -- distributed cache ---------------------------------------------------------


def test_ring_minimal_movement():
    ring = HashRing(vnodes=64)
    for i in range(4):
        ring.add(f"n{i}")
    keys = [f"key-{i}" for i in range(500)]
    before = {k: ring.nodes_for(k, 1)[0] for k in keys}
    ring.add("n4")
    after = {k: ring.nodes_for(k, 1)[0] for k in keys}
    moved = sum(before[k] != after[k] for k in keys)
    assert moved < len(keys) * 0.45  # ~1/5 expected, allow slack


def test_distributed_cache_survives_node_failure():
    dc = DistributedPlanCache(4, replication=2, capacity_per_node=64)
    for i in range(40):
        dc.insert(f"kw-{i}", i)
    dc.mark_down("cache-2")
    assert all(dc.lookup(f"kw-{i}") == i for i in range(40))


def test_distributed_cache_data_loss_without_replication():
    dc = DistributedPlanCache(4, replication=1, capacity_per_node=64)
    for i in range(40):
        dc.insert(f"kw-{i}", i)
    dc.mark_down("cache-1")
    hits = sum(dc.lookup(f"kw-{i}") is not None for i in range(40))
    assert hits < 40  # r=1 must lose the downed node's keys


def test_distributed_cache_fuzzy_shards_and_batch_lookup():
    dc = DistributedPlanCache(
        4, replication=2, capacity_per_node=64, fuzzy=True, fuzzy_threshold=0.7
    )
    dc.insert("working capital ratio", "wc")
    dc.insert("net revenue growth", "nr")
    # fuzzy resolution happens inside the owning shard's index
    assert dc.lookup("working capital ratio analysis") == "wc"
    out = dc.lookup_batch(
        ["net revenue growth", "net revenue growth 2023", "zz unrelated zz"]
    )
    assert out[0] == "nr" and out[1] == "nr" and out[2] is None
    # elastic add keeps shard indexes in sync through rebalancing
    dc.add_node("cache-9")
    assert dc.lookup("working capital ratio analysis") == "wc"


def test_graceful_remove_rehomes_keys():
    dc = DistributedPlanCache(4, replication=1, capacity_per_node=64)
    for i in range(30):
        dc.insert(f"kw-{i}", i)
    dc.remove_node("cache-0")
    assert all(dc.lookup(f"kw-{i}") == i for i in range(30))


# -- speculative prefetch -------------------------------------------------------


def test_keyword_predictor_learns_bigram():
    p = KeywordPredictor()
    for _ in range(5):
        p.observe("a")
        p.observe("b")
    p.observe("a")
    assert p.predict() == ["b"]


def test_prefetcher_touches_lru():
    cache = PlanCache(capacity=2)
    cache.insert("b", 2)
    cache.insert("c", 3)
    pred = KeywordPredictor()
    pf = SpeculativePrefetcher(cache, pred)
    for _ in range(3):
        pf.on_request("a")
        pf.on_request("b")
    # 'b' predicted after 'a' -> touched -> should survive an insert
    pf.on_request("a")
    cache.insert("d", 4)
    assert "b" in cache
    assert pf.prefetches > 0

"""Property suite for the step-level undo/commit journal (ISSUE 10).

The safety contract speculation rests on: for ANY interleaving of
``record`` / ``commit`` / ``patch`` / ``rollback``, the surviving state of
every effect surface — env workspace, plan cache, metrics registry — is
byte-identical to a never-speculated sequential run that executes only
the steps that ultimately committed, in record order. Rolled-back steps
leave no residue anywhere.

The property runs twice: under Hypothesis when it is installed (arbitrary
shrinkable interleavings), and ALWAYS under a seeded deterministic
generator (several hundred random programs), so the guarantee is
exercised on machines without Hypothesis too.
"""

from __future__ import annotations

import json
import random

import pytest

from repro.core.cache import PlanCache
from repro.core.journal import StepJournal
from repro.envs.base import Workspace
from repro.obs.registry import MetricsRegistry
from repro.sim.clock import VirtualClock

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # the image may not ship hypothesis; the seeded
    HAVE_HYPOTHESIS = False  # fallback below still proves the property

WS_KEYS = ("a", "b", "c", "d")  # small pool so writes collide and nest


# -- the interpreter ---------------------------------------------------------


def drive(ops, resolve_by_commit):
    """Run one record/commit/patch/rollback program through the journal.

    Returns (state, committed_effects) where committed_effects is the
    record-ordered list of (ws_key, value, template_key) for exactly the
    steps the program committed — the input to the sequential reference.
    """
    clock = VirtualClock(1.0)
    ws = Workspace()
    cache = PlanCache(capacity=64, clock=clock)
    metrics = MetricsRegistry()
    journal = StepJournal()
    committed = []  # effects whose step committed, in commit (=record) order
    open_fx = []  # effects of currently-open steps, parallel to the journal
    serial = 0

    for op in ops:
        kind = op[0]
        if kind == "record":
            _, key, value = op
            tpl = f"tpl-{serial}"  # unique per step: admissions are disjoint
            serial += 1
            step = journal.begin_step(label=tpl)
            step.applied(ws.write(key, value))  # eager, compensated
            token = cache.now()
            clock.advance(0.001)
            step.on_commit(
                lambda k=tpl, v=value, t=token:
                    cache.insert_batch([(k, v)], unless_written_since=t))
            step.on_commit(
                lambda: metrics.counter("journal.test_commits").inc())
            open_fx.append((key, value, tpl))
        elif kind == "commit":
            n = journal.commit(op[1])
            committed.extend(open_fx[:n])
            del open_fx[:n]
        elif kind == "rollback":
            journal.rollback(from_step=min(op[1], journal.open_steps()))
            del open_fx[min(op[1], len(open_fx)):]
        elif kind == "patch":
            n_committed, _ = journal.patch(keep=op[1])
            committed.extend(open_fx[:n_committed])
            open_fx.clear()
        else:  # pragma: no cover - generator bug
            raise AssertionError(f"unknown op {op!r}")

    # quiesce: a real speculation always resolves every step
    if resolve_by_commit:
        committed.extend(open_fx[:journal.commit()])
    else:
        journal.rollback()
    assert journal.open_steps() == 0
    conserved = journal.steps_committed + journal.steps_rolled_back
    assert journal.steps_recorded == conserved
    return (ws, cache, metrics), committed


def reference(committed_effects):
    """The never-speculated sequential run: only the surviving steps."""
    clock = VirtualClock(1.0)
    ws = Workspace()
    cache = PlanCache(capacity=64, clock=clock)
    metrics = MetricsRegistry()
    for key, value, tpl in committed_effects:
        ws.write(key, value)
        cache.insert_batch([(tpl, value)])
        metrics.counter("journal.test_commits").inc()
        clock.advance(0.001)
    return ws, cache, metrics


def state_bytes(state):
    """Canonical byte serialization of (workspace, cache, metrics)."""
    ws, cache, metrics = state
    return json.dumps({
        "workspace": ws.snapshot(),
        "cache": cache.snapshot_items(),
        "metrics": metrics.snapshot(),
    }, sort_keys=True).encode()


def assert_equivalent(ops, resolve_by_commit):
    state, committed = drive(ops, resolve_by_commit)
    assert state_bytes(state) == state_bytes(reference(committed))


# -- arbitrary interleavings -------------------------------------------------


def gen_program(rng, max_len=40):
    ops = []
    for _ in range(rng.randrange(max_len + 1)):
        r = rng.random()
        if r < 0.55:
            ops.append(("record", rng.choice(WS_KEYS), rng.randrange(100)))
        elif r < 0.70:
            ops.append(("commit", rng.randrange(5)))
        elif r < 0.85:
            ops.append(("rollback", rng.randrange(5)))
        else:
            ops.append(("patch", rng.randrange(5)))
    return ops, rng.random() < 0.5


def test_property_seeded_interleavings():
    """400 seeded random programs — runs with or without Hypothesis."""
    rng = random.Random(0xA9C)
    for _ in range(400):
        ops, by_commit = gen_program(rng)
        assert_equivalent(ops, by_commit)


if HAVE_HYPOTHESIS:
    _op = st.one_of(
        st.tuples(st.just("record"), st.sampled_from(WS_KEYS),
                  st.integers(0, 99)),
        st.tuples(st.just("commit"), st.integers(0, 5)),
        st.tuples(st.just("rollback"), st.integers(0, 5)),
        st.tuples(st.just("patch"), st.integers(0, 5)),
    )

    @settings(max_examples=200, deadline=None)
    @given(st.lists(_op, max_size=40), st.booleans())
    def test_property_hypothesis_interleavings(ops, by_commit):
        assert_equivalent(list(ops), by_commit)
else:
    @pytest.mark.skip(reason="hypothesis not installed; seeded fallback "
                             "test_property_seeded_interleavings covers it")
    def test_property_hypothesis_interleavings():
        pass  # pragma: no cover


# -- directed edges ----------------------------------------------------------


def test_commit_runs_deferred_actions_in_record_order():
    j, order = StepJournal(), []
    for i in range(3):
        s = j.begin_step()
        s.on_commit(lambda i=i: order.append(i))
    assert j.commit() == 3
    assert order == [0, 1, 2]


def test_rollback_unwinds_compensations_in_reverse_order():
    j, ws = StepJournal(), Workspace()
    ws.write("k", "base")
    for i in range(3):  # nested overwrites of the same key
        s = j.begin_step()
        s.applied(ws.write("k", f"spec-{i}"))
    assert ws.read("k") == "spec-2"
    assert j.rollback() == 3
    assert ws.read("k") == "base"  # newest-first unwinding restores base
    assert ws.compensations_run == 3


def test_partial_commit_finalizes_prefix_only():
    j, fired = StepJournal(), []
    for i in range(4):
        s = j.begin_step()
        s.on_commit(lambda i=i: fired.append(i))
    assert j.commit(upto=2) == 2
    assert fired == [0, 1]
    assert j.open_steps() == 2
    assert j.rollback() == 2
    assert fired == [0, 1]


def test_patch_splices_matching_prefix_and_divergent_suffix():
    j, ws = StepJournal(), Workspace()
    fired = []
    for i in range(3):
        s = j.begin_step()
        s.applied(ws.write(f"r{i}", f"spec-{i}"))
        s.on_commit(lambda i=i: fired.append(i))
    n_committed, rolled = j.patch(keep=1)
    assert (n_committed, rolled) == (1, 2)
    assert fired == [0]
    assert ws.snapshot() == {"r0": "spec-0"}
    # the journal stays usable: the re-executed suffix records into it
    s = j.begin_step()
    s.applied(ws.write("r1", "verified-1"))
    assert j.commit() == 1
    assert ws.snapshot() == {"r0": "spec-0", "r1": "verified-1"}


def test_rollback_from_step_out_of_range_raises():
    j = StepJournal()
    j.begin_step()
    with pytest.raises(ValueError):
        j.rollback(from_step=2)
    with pytest.raises(ValueError):
        j.rollback(from_step=-1)
    with pytest.raises(ValueError):
        j.commit(upto=-1)


def test_deferred_admission_loses_to_newer_write():
    """The token captured at record time guards the late commit: an entry
    (re)written after the token must survive the deferred admission."""
    clock = VirtualClock(1.0)
    cache = PlanCache(capacity=8, clock=clock)
    j = StepJournal()
    step = j.begin_step()
    token = cache.now()
    step.on_commit(lambda: cache.insert_batch(
        [("kw", "stale-speculated")], unless_written_since=token))
    clock.advance(1.0)
    cache.insert_batch([("kw", "fresh-client-write")])  # concurrent writer
    j.commit()
    assert cache.peek("kw") == "fresh-client-write"
    assert cache.stats.stale_insert_skips == 1


def test_open_steps_is_the_liveness_surface():
    j = StepJournal()
    assert j.open_steps() == 0
    j.begin_step(); j.begin_step()
    assert j.open_steps() == 2  # what the sim's spec_liveness oracle reads
    j.commit(upto=1)
    assert j.open_steps() == 1
    j.rollback()
    assert j.open_steps() == 0

"""The Pallas attention path inside the model must match the jnp path."""

import dataclasses

import jax
import numpy as np

from repro.configs import registry
from repro.models import lm


def test_model_forward_with_pallas_matches_jnp(rng_key):
    cfg = registry.get_smoke("qwen2.5-3b")
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    p = lm.init_params(cfg, rng_key)
    B, S = 1, 256  # S % 128 == 0 -> kernel path eligible
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    l_jnp, _, _ = lm.forward(cfg, p, {"tokens": tok})
    cfg_k = dataclasses.replace(cfg, use_pallas=True)
    l_ker, _, _ = lm.forward(cfg_k, p, {"tokens": tok})
    a = np.asarray(l_jnp, np.float32)
    b = np.asarray(l_ker, np.float32)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 2e-3, rel

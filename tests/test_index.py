"""repro.index subsystem: kernel parity, bank invariants, LSH consistency.

Pallas ``batch_topk`` runs in interpret mode on this CPU container; the
parity sweep pins it to the numpy oracle (``ref.topk_cosine_ref``) on
scores (atol 1e-5) AND indices — drift here is the signal the CI smoke
workflow exists to catch.
"""

import threading

import numpy as np
import pytest

from repro.core.cache import PlanCache
from repro.core.fuzzy import FuzzyMatcher
from repro.index import DIM, EmbeddingBank, SimilarityIndex, embed, embed_batch
from repro.index.bucketed import BucketedIndex, _brute_topk

RNG = np.random.RandomState(7)


def _unit_rows(n, seed=0):
    m = np.random.RandomState(seed).randn(n, DIM).astype(np.float32)
    m /= np.maximum(np.linalg.norm(m, axis=1, keepdims=True), 1e-9)
    return m


# -- Pallas kernel vs numpy oracle -------------------------------------------


@pytest.mark.parametrize("n", [0, 1, 17, 1000])
@pytest.mark.parametrize("k", [1, 8])
@pytest.mark.parametrize("q", [1, 5])
def test_batch_topk_matches_ref(n, k, q):
    from repro.kernels import ops, ref

    queries = _unit_rows(q, seed=n * 10 + k)
    bank = _unit_rows(n, seed=n + 1)
    s, i = ops.batch_topk(queries, bank, k=k)
    rs, ri = ref.topk_cosine_ref(queries, bank, k)
    np.testing.assert_allclose(np.asarray(s), rs, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), ri)


def test_batch_topk_nonsquare_blocks():
    """N and Q far from block multiples (forces the padding path)."""
    from repro.kernels import ops, ref

    queries = _unit_rows(130, seed=3)
    bank = _unit_rows(1025, seed=4)
    s, i = ops.batch_topk(queries, bank, k=4, block_q=64, block_n=256)
    rs, ri = ref.topk_cosine_ref(queries, bank, 4)
    np.testing.assert_allclose(np.asarray(s), rs, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), ri)


# -- batched embedding --------------------------------------------------------


def test_embed_batch_matches_single():
    texts = ["working capital ratio", "net revenue 2023", "", "mean calculation"]
    batch = embed_batch(texts)
    for r, t in enumerate(texts):
        np.testing.assert_array_equal(batch[r], embed(t))
    norms = np.linalg.norm(batch, axis=1)
    assert norms[2] == 0.0  # empty text -> zero row, not NaN
    np.testing.assert_allclose(norms[[0, 1, 3]], 1.0, atol=1e-6)


# -- EmbeddingBank invariants -------------------------------------------------


def test_bank_add_remove_freelist_reuse():
    b = EmbeddingBank(initial_capacity=2)
    s0 = b.add("alpha")
    s1 = b.add("beta")
    s2 = b.add("gamma")  # forces growth
    assert len(b) == 3 and {s0, s1, s2} == {0, 1, 2}
    assert b.add("alpha") == s0  # idempotent re-add
    b.remove("beta")
    assert len(b) == 2 and b.key_of(s1) is None
    assert np.all(b.matrix()[s1] == 0.0)  # tombstoned row scores 0
    assert b.add("delta") == s1  # freelist reuses the freed slot
    assert b.key_of(s1) == "delta"
    np.testing.assert_array_equal(b.vector("delta"), embed("delta"))


def test_bank_random_ops_consistent_with_dict():
    b = EmbeddingBank(initial_capacity=4)
    model = {}
    for step in range(300):
        key = f"key-{RNG.randint(40)}"
        if RNG.rand() < 0.6:
            b.add(key)
            model[key] = True
        else:
            b.remove(key)
            model.pop(key, None)
        assert len(b) == len(model)
    assert sorted(b.keys()) == sorted(model)
    for k in model:
        slot = b.slot_of(k)
        assert b.key_of(slot) == k
        np.testing.assert_array_equal(b.matrix()[slot], embed(k))


# -- BucketedIndex ------------------------------------------------------------


def test_bucketed_finds_exact_entry_and_tracks_removal():
    bank = EmbeddingBank()
    idx = BucketedIndex(bank, n_bits=10, scan_threshold=0)
    keys = [f"intent keyword number {i}" for i in range(50)]
    for k in keys:
        idx.on_add(bank.add(k), bank.vector(k))
    q = embed(keys[17])
    scores, slots = idx.topk(q[None], k=1)
    assert bank.key_of(int(slots[0, 0])) == keys[17]
    assert scores[0, 0] == pytest.approx(1.0, abs=1e-6)
    # removal drops it from its bucket: the same probe can't return it
    idx.on_remove(bank.remove(keys[17]))
    _, slots = idx.topk(q[None], k=1)
    assert slots[0, 0] == -1 or bank.key_of(int(slots[0, 0])) != keys[17]


def test_bucketed_fallback_matches_brute_below_threshold():
    bank = EmbeddingBank()
    idx = BucketedIndex(bank, n_bits=8, scan_threshold=10_000)
    M = _unit_rows(300, seed=9)
    for i in range(300):
        idx.on_add(bank.add(f"k{i}", M[i]), M[i])
    q = _unit_rows(4, seed=11)
    s_idx, i_idx = idx.topk(q, k=3)
    s_ref, i_ref = _brute_topk(bank.matrix(), q, 3)
    np.testing.assert_allclose(s_idx, s_ref, atol=1e-6)
    np.testing.assert_array_equal(i_idx, i_ref)


def test_bucketed_slot_reuse_rehashes_signature():
    bank = EmbeddingBank()
    idx = BucketedIndex(bank, n_bits=12, scan_threshold=0)
    slot = bank.add("first key about revenue")
    idx.on_add(slot, bank.vector("first key about revenue"))
    idx.on_remove(bank.remove("first key about revenue"))
    slot2 = bank.add("completely different topic entirely")
    assert slot2 == slot  # freelist reuse
    idx.on_add(slot2, bank.vector("completely different topic entirely"))
    q = embed("completely different topic entirely")
    _, slots = idx.topk(q[None], k=1)
    assert bank.key_of(int(slots[0, 0])) == "completely different topic entirely"


# -- SimilarityIndex facade (all backends agree) ------------------------------


@pytest.mark.parametrize("backend", ["brute", "pallas", "bucketed", "auto"])
def test_similarity_index_backends_agree(backend):
    idx = SimilarityIndex(backend=backend)
    keys = [f"intent keyword number {i}" for i in range(40)]
    for k in keys:
        idx.add(k)
    assert idx.best_match("intent keyword number 7", threshold=0.8) == keys[7]
    assert idx.best_match("zz qq xx totally unrelated", threshold=0.99) is None
    idx.remove(keys[7])
    got = idx.best_match("intent keyword number 7", threshold=0.99)
    assert got != keys[7]
    batch = idx.best_match_batch(
        ["intent keyword number 3", "intent keyword number 12"], threshold=0.8
    )
    assert batch == [keys[3], keys[12]]


def test_similarity_index_topk_never_returns_tombstones():
    idx = SimilarityIndex(backend="brute")
    for kw in ("alpha beta", "gamma delta", "epsilon zeta"):
        idx.add(kw)
    idx.remove("gamma delta")
    # query anti-correlated with everything: the freed zero row would
    # rank first at score 0.0 if not masked
    q = -idx.bank.vector("alpha beta")
    scores, slots = idx.topk(q.reshape(1, -1), k=3)
    for c in range(3):
        assert slots[0, c] == -1 or idx.bank.key_of(int(slots[0, c])) is not None
        if slots[0, c] == -1:
            assert scores[0, c] <= -1e29


def test_pallas_backend_does_not_retrace_per_insert():
    from repro.kernels import ops

    before = ops.batch_topk._cache_size()
    idx = SimilarityIndex(backend="pallas", initial_capacity=64)
    for i in range(5):  # stays within one arena capacity
        idx.add(f"key number {i}")
        idx.best_match("key number 0", threshold=0.8)
    assert ops.batch_topk._cache_size() - before <= 1


# -- FuzzyMatcher / PlanCache integration ------------------------------------


def test_fuzzy_matcher_compat_keys_argument():
    m = FuzzyMatcher()
    m.add("stale key")
    # external key-set reconciliation (seed API): stale removed, new added
    assert m.best_match("fresh key", ["fresh key"], threshold=0.9) == "fresh key"
    assert m.best_match("stale key", threshold=0.99) != "stale key"


def test_plan_cache_ttl_expiry_keeps_index_in_sync():
    from repro.sim.clock import VirtualClock

    clock = VirtualClock()
    c = PlanCache(capacity=10, fuzzy=True, fuzzy_threshold=0.7, ttl_s=2.0,
                  clock=clock)
    c.insert("net profit margin analysis", 1)
    assert c.lookup("net profit margin analysis") == 1
    assert len(c._matcher.index) == 1
    clock.advance(2.1)
    assert c.lookup("net profit margin analysis") is None  # expired
    # the expired key must be gone from the fuzzy index too, not just _store
    assert len(c._matcher.index) == 0


def test_plan_cache_lookup_batch_mixed_hits():
    c = PlanCache(capacity=10, fuzzy=True, fuzzy_threshold=0.7)
    c.insert("working capital ratio", "wc")
    c.insert("net revenue growth", "nr")
    out = c.lookup_batch(
        ["working capital ratio",          # exact hit
         "working capital ratio analysis", # fuzzy hit
         "quantum chromodynamics"]         # miss
    )
    assert out == ["wc", "wc", None]
    assert c.stats.hits == 2 and c.stats.misses == 1


def test_plan_cache_concurrent_fuzzy_ops_stay_consistent():
    c = PlanCache(capacity=32, fuzzy=True, fuzzy_threshold=0.8)
    errors = []

    def writer(tid):
        try:
            for i in range(120):
                c.insert(f"keyword {tid} number {i}", i)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    def reader():
        try:
            for i in range(200):
                c.lookup(f"keyword 0 number {i % 120}")
                len(c)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(t,)) for t in range(3)]
    threads += [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(c) <= 32
    # index and store agree exactly after the storm
    assert sorted(c._matcher.index.bank.keys()) == sorted(c.keys())


# -- LSH auto-tuning (telemetry loop closed) ----------------------------------


def _bank_of(vectors):
    bank = EmbeddingBank(initial_capacity=len(vectors))
    idx_slots = []
    with bank.lock:
        for i, v in enumerate(vectors):
            idx_slots.append(bank.add(f"k{i}", v))
    return bank, idx_slots


def test_lsh_autotune_converges_on_drifting_workload():
    """A workload that drifts 10x larger drives avg_candidates up; periodic
    autotune grows n_bits until candidates fall back under target, then
    goes quiet (converged)."""
    rng = np.random.RandomState(0)
    vectors = _unit_rows(6000, seed=1)
    bank = EmbeddingBank(initial_capacity=64)
    idx = BucketedIndex(bank, n_tables=4, n_bits=6, probe_hamming=1,
                        scan_threshold=0, recall_sample_every=0)

    def grow_to(n, start):
        with bank.lock:
            for i in range(start, n):
                slot = bank.add(f"k{i}", vectors[i])
                idx.on_add(slot, vectors[i])

    queries = _unit_rows(80, seed=2)
    actions = []
    sizes = [500, 2000, 6000]
    prev = 0
    for size in sizes:  # the drift: the bank keeps growing
        grow_to(size, prev)
        prev = size
        for _ in range(6):  # tuning windows per phase
            for q in queries:
                idx.best_slot(q)
            act = idx.autotune(target_candidates=96, min_queries=50)
            if act is None:
                break
            actions.append(act)

    assert actions, "autotune never acted on a 10x drift"
    assert idx.n_bits > 6  # candidate pressure grew the tables
    # converged: a fresh window triggers no further action and candidate
    # cost is back near target
    for q in queries:
        idx.best_slot(q)
    assert idx.autotune(target_candidates=96, min_queries=50) is None
    snap = idx.telemetry.snapshot()
    assert snap["avg_candidates"] <= 96 * 2


def test_lsh_autotune_widens_probe_on_low_recall():
    """With one table and no multi-probe, sampled live recall is poor;
    autotune widens probe_hamming (masks-only, no rebuild) up to its cap."""
    vectors = _unit_rows(3000, seed=3)
    bank = EmbeddingBank(initial_capacity=4096)
    with bank.lock:
        slots = [bank.add(f"k{i}", v) for i, v in enumerate(vectors)]
    idx = BucketedIndex(bank, n_tables=1, n_bits=12, probe_hamming=0,
                        scan_threshold=0, recall_sample_every=1)
    queries = _unit_rows(120, seed=4)
    actions = []
    for _ in range(4):
        for q in queries:
            idx.best_slot(q)
        act = idx.autotune(min_queries=50)
        if act is None:
            break
        actions.append(act)
    assert actions[:1] == ["probe_hamming->1"]
    assert idx.probe_hamming >= 1  # telemetry drove the widening
    # geometry survived: probing still answers and masks match n_bits
    s, slot = idx.best_slot(queries[0])
    assert slot == -1 or 0 <= slot < 4096


def test_similarity_index_autotune_facade():
    idx = SimilarityIndex(backend="brute")
    assert idx.autotune() is None  # no LSH tables to tune
    idx2 = SimilarityIndex(backend="bucketed")
    assert idx2.autotune() is None  # thin window: no action, no crash


def test_plan_cache_autotune_reaches_fuzzy_stage():
    c = PlanCache(capacity=16, fuzzy=True, index_backend="bucketed")
    c.insert("net revenue growth", 1)
    assert c.autotune() == []  # thin window -> no actions, plumbing intact

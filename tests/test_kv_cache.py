"""Paged KV prefix cache: page refcount lifecycle, copy-on-write
extension, plan-cache eviction coupling, and prefix-prefill parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.cache import PlanCache
from repro.models import lm
from repro.obs import MetricsRegistry
from repro.serving.engine import Engine
from repro.serving.kv_cache import (
    CachePoint,
    KVPrefixCache,
    PagePool,
    PagePoolExhausted,
    plan_cache_point,
    pool_for_config,
)
from repro.serving.router import TwoTierRouter


def _pool(num_pages=16, page_size=4):
    return PagePool(2, num_pages, page_size, 2, 8, dtype=jnp.float32)


def _kv(L=2, S=10, fill=None):
    if fill is None:
        x = jnp.arange(L * S * 2 * 8, dtype=jnp.float32).reshape(L, S, 2, 8)
    else:
        x = jnp.full((L, S, 2, 8), float(fill), jnp.float32)
    return x


# -- page pool / refcounts -----------------------------------------------------


def test_page_refcount_lifecycle():
    pool = _pool()
    kv = KVPrefixCache(pool)
    kv.put("a", _kv(S=10), _kv(S=10), length=10)  # 3 pages (4+4+2)
    pages = list(kv._entries["a"].pages)
    assert pool.free_pages == 13
    assert all(pool.refcount[p] == 1 for p in pages)

    lease = kv.acquire("a")
    assert lease is not None and lease.length == 10
    assert all(pool.refcount[p] == 2 for p in pages)

    # release while leased: entry goes, pages survive via the lease
    assert kv.release("a")
    assert "a" not in kv
    assert all(pool.refcount[p] == 1 for p in pages)
    k, v, ln = kv.gather(lease, batch=2)
    assert k.shape == (2, 2, 12, 2, 8) and ln == 10

    kv.release_lease(lease)
    assert pool.free_pages == 16
    assert all(pool.refcount[p] == 0 for p in pages)


def test_put_roundtrips_content_with_page_padding():
    pool = _pool()
    kv = KVPrefixCache(pool)
    src = _kv(S=10)
    kv.put("a", src, src, length=7)  # 2 pages, last padded by 1
    lease = kv.acquire("a")
    k, v, ln = kv.gather(lease, batch=1)
    assert ln == 7
    np.testing.assert_array_equal(np.asarray(k[:, 0, :7]), np.asarray(src[:, :7]))
    np.testing.assert_array_equal(
        np.asarray(k[:, 0, 7:]), np.zeros((2, 1, 2, 8), np.float32)
    )
    kv.release_lease(lease)


def test_cow_extend_shares_full_pages_and_copies_tail():
    pool = _pool()
    kv = KVPrefixCache(pool)
    parent = _kv(S=10)
    kv.put("p", parent, parent, length=10)
    ppages = list(kv._entries["p"].pages)
    n_new = kv.extend("p", "c", _kv(S=5, fill=1.0), _kv(S=5, fill=1.0))
    cpages = list(kv._entries["c"].pages)
    assert n_new == 2  # tail(2) + 5 suffix = 7 -> 2 pages
    assert cpages[:2] == ppages[:2]  # full pages shared, not copied
    assert cpages[2] != ppages[2]  # partial tail page copied (COW)
    assert pool.refcount[ppages[0]] == 2

    lease = kv.acquire("c")
    k, _, ln = kv.gather(lease, batch=1)
    assert ln == 15
    expect = np.concatenate(
        [np.asarray(parent), np.ones((2, 5, 2, 8), np.float32)], axis=1
    )
    np.testing.assert_array_equal(np.asarray(k[:, 0, :15]), expect)
    kv.release_lease(lease)

    # parent release leaves shared pages alive for the child
    kv.release("p")
    assert pool.refcount[ppages[0]] == 1
    kv.release("c")
    assert pool.free_pages == 16


def test_lru_eviction_on_pool_exhaustion_and_lease_pinning():
    pool = _pool(num_pages=4)
    kv = KVPrefixCache(pool)
    kv.put("old", _kv(S=8), _kv(S=8))  # 2 pages
    kv.put("new", _kv(S=8), _kv(S=8))  # 2 pages, pool full
    lease = kv.acquire("new")
    with pytest.raises(PagePoolExhausted):
        # "old" can be evicted (2 pages) but "new" is leased -> only 2 free
        kv.put("x", _kv(S=16), _kv(S=16))
    assert "old" not in kv  # the idle LRU victim went first
    kv.release_lease(lease)
    kv.put("x", _kv(S=16), _kv(S=16))  # now "new" is evictable
    assert "new" not in kv and "x" in kv
    assert kv._prefix_evictions.value == 2


def test_extend_under_pool_pressure_pins_parent():
    """extend() must never evict its own parent to satisfy the child's
    allocation: with the parent as the only (idle) entry and too few free
    pages, the extend fails loudly and the parent survives intact."""
    pool = _pool(num_pages=4)
    kv = KVPrefixCache(pool)
    parent = _kv(S=10)
    kv.put("p", parent, parent, length=10)  # 3 pages, 1 free
    with pytest.raises(PagePoolExhausted):
        kv.extend("p", "c", _kv(S=5, fill=1.0), _kv(S=5, fill=1.0))  # needs 2
    assert "p" in kv and "c" not in kv
    assert kv._entries["p"].leases == 0  # the extend pin was released
    lease = kv.acquire("p")
    k, _, ln = kv.gather(lease, batch=1)
    assert ln == 10
    np.testing.assert_array_equal(np.asarray(k[:, 0, :10]), np.asarray(parent))
    kv.release_lease(lease)


def test_extend_under_pool_pressure_evicts_idle_not_parent():
    pool = _pool(num_pages=6)
    kv = KVPrefixCache(pool)
    parent = _kv(S=10)
    kv.put("idle", _kv(S=8), _kv(S=8))  # 2 pages
    kv.put("p", parent, parent, length=10)  # 3 pages, 1 free
    n_new = kv.extend("p", "c", _kv(S=5, fill=1.0), _kv(S=5, fill=1.0))
    assert n_new == 2
    assert "idle" not in kv and "p" in kv  # the bystander went, not the parent
    lease = kv.acquire("c")
    k, _, ln = kv.gather(lease, batch=1)
    assert ln == 15
    expect = np.concatenate(
        [np.asarray(parent), np.ones((2, 5, 2, 8), np.float32)], axis=1
    )
    np.testing.assert_array_equal(np.asarray(k[:, 0, :15]), expect)
    kv.release_lease(lease)


def test_alloc_over_capacity_fails_fast_without_evicting():
    """A request larger than the whole pool must refuse up front, not
    flush every cached prefix first and then fail anyway."""
    pool = _pool(num_pages=4)
    kv = KVPrefixCache(pool)
    kv.put("a", _kv(S=8), _kv(S=8))  # 2 pages
    with pytest.raises(PagePoolExhausted, match="holds only"):
        kv.put("x", _kv(S=32), _kv(S=32))  # 8 pages > 4 total
    assert "a" in kv and pool.free_pages == 2
    assert kv._prefix_evictions.value == 0


def test_metrics_land_in_registry():
    reg = MetricsRegistry()
    kv = KVPrefixCache(_pool(), obs=reg)
    kv.put("a", _kv(S=8), _kv(S=8))
    lease = kv.acquire("a")
    kv.gather(lease, batch=4)
    kv.release_lease(lease)
    kv.release("a")
    assert reg.counter("kv.pages_built").value == 2
    assert reg.counter("kv.pages_hit").value == 2
    assert reg.counter("kv.tokens_prefetched").value == 32  # 4 * 8
    assert reg.counter("kv.prefix_evictions").value == 1


# -- page table for the paged kernel -------------------------------------------


def test_page_table_calling_convention():
    kv = KVPrefixCache(_pool())
    kv.put("a", _kv(S=10), _kv(S=10))  # 3 pages
    kv.put("b", _kv(S=3), _kv(S=3))  # 1 page
    la, lb = kv.acquire("a"), kv.acquire("b")
    table, lengths = kv.page_table([la, lb])
    assert table.shape == (2, 3) and lengths.tolist() == [10, 3]
    assert table[0].tolist() == list(la.pages)
    assert table[1, 0] == lb.pages[0] and table[1, 1] == -1
    kv.release_lease(la)
    kv.release_lease(lb)


# -- the single cache point -----------------------------------------------------


def test_plan_cache_point_placement():
    tpl = np.asarray([5, 6, 7], np.int32)
    prompts = np.asarray([[5, 6, 7, 1, 2], [5, 6, 7, 3, 4]], np.int32)
    cp = plan_cache_point("t", tpl, prompts)
    assert cp == CachePoint("t", 3)
    # unsafe placements: prompt diverges from the template, or no suffix
    assert plan_cache_point("t", tpl, prompts[:, [0, 2, 1, 3, 4]]) is None
    assert plan_cache_point("t", tpl, prompts[:, :3]) is None
    assert plan_cache_point("t", np.asarray([], np.int32), prompts) is None


# -- plan-cache lifecycle coupling ----------------------------------------------


def test_plan_cache_eviction_frees_prefix_pages():
    pool = _pool()
    kv = KVPrefixCache(pool)
    cache = PlanCache(capacity=2)
    TwoTierRouter(
        cache,
        extract_keyword=lambda r: r,
        plan_large=lambda r: "L",
        plan_small_with_template=lambda r, t: "S",
        make_template=lambda r, x: {"t": r},
        async_cachegen=False,
        kv_prefix=kv,
    )
    for kw in ("a", "b"):
        cache.insert(kw, {"t": kw})
        kv.put(kw, _kv(S=8), _kv(S=8))
    cache.insert("c", {"t": "c"})  # LRU-evicts "a" from the plan cache
    assert "a" not in kv and "b" in kv  # pages freed with the template
    assert cache.stats.evictions == 1
    cache.remove("b")
    assert "b" not in kv
    cache.clear()
    assert len(kv) == 0 and pool.free_pages == 16


def test_insert_overwrite_fires_evict_listeners_and_frees_prefix():
    """Regenerating a template under the same keyword must evict the OLD
    template's derived state: a silent _store swap would leave the stale
    prefix KV registered under the same id and later hits would serve it."""
    pool = _pool()
    kv = KVPrefixCache(pool)
    cache = PlanCache(capacity=4)
    cache.add_evict_listener(kv.release)
    seen = []
    cache.add_evict_listener(seen.append)
    cache.insert("a", {"t": 1})
    kv.put("a", _kv(S=8), _kv(S=8))
    assert "a" in kv
    cache.insert("a", {"t": 2})  # regenerated plan, same keyword
    assert seen == ["a"]
    assert "a" not in kv  # stale prefix pages freed with the old template
    assert pool.free_pages == 16
    assert cache.lookup("a") == {"t": 2}
    assert cache.stats.evictions == 0  # a replace is not an eviction


def test_router_kv_prefix_requires_evict_listener():
    class Bare:
        def lookup(self, kw):
            return None

    with pytest.raises(TypeError):
        TwoTierRouter(
            Bare(),
            extract_keyword=lambda r: r,
            plan_large=lambda r: "L",
            plan_small_with_template=lambda r, t: "S",
            make_template=lambda r, x: None,
            kv_prefix=KVPrefixCache(_pool()),
        )


# -- engine integration ---------------------------------------------------------


@pytest.fixture(scope="module")
def prefix_engine():
    cfg = registry.get_smoke("olmo-1b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    kv = KVPrefixCache(pool_for_config(cfg, num_pages=32, page_size=8))
    return Engine(cfg, params, max_len=64, kv_prefix=kv), kv


def test_prefill_with_prefix_matches_full_prefill(prefix_engine):
    """Suffix-only prefill against pooled template KV reproduces the full
    prefill: same last-token logits, same cache contents, same greedy
    continuation."""
    eng, kv = prefix_engine
    rs = np.random.RandomState(0)
    B, Sp, Ss = 4, 20, 8
    tpl = rs.randint(3, 400, (Sp,)).astype(np.int32)
    suffix = rs.randint(3, 400, (B, Ss)).astype(np.int32)
    toks = np.concatenate([np.broadcast_to(tpl, (B, Sp)), suffix], axis=1)

    assert eng.prefill_with_prefix("tpl", suffix) is None  # cold: no prefix
    logits_full, cache_full = eng.prefill(toks)
    assert eng.register_prefix("tpl", cache_full, Sp)
    reused0 = eng.stats.prefix_tokens_reused

    res = eng.prefill_with_prefix("tpl", suffix)
    assert res is not None
    logits_pfx, cache_pfx = res
    np.testing.assert_allclose(logits_full, logits_pfx, atol=2e-4, rtol=2e-4)
    assert int(cache_pfx["length"]) == Sp + Ss
    np.testing.assert_allclose(
        np.asarray(cache_full["kv_k"][:, :, : Sp + Ss], np.float32),
        np.asarray(cache_pfx["kv_k"][:, :, : Sp + Ss], np.float32),
        atol=2e-2,
    )
    assert eng.stats.prefix_tokens_reused - reused0 == B * Sp

    # generate() takes the same route through a CachePoint
    cp = plan_cache_point("tpl", tpl, toks)
    a = eng.generate(toks, max_new=5)
    b = eng.generate(toks, max_new=5, cache_point=cp)
    np.testing.assert_array_equal(a, b)


def test_generate_registers_prefix_on_pool_miss(prefix_engine):
    eng, kv = prefix_engine
    rs = np.random.RandomState(1)
    tpl = rs.randint(3, 400, (16,)).astype(np.int32)
    toks = np.concatenate(
        [np.broadcast_to(tpl, (2, 16)), rs.randint(3, 400, (2, 6)).astype(np.int32)],
        axis=1,
    )
    cp = plan_cache_point("fresh-tpl", tpl, toks)
    assert "fresh-tpl" not in kv
    eng.generate(toks, max_new=3, cache_point=cp)  # miss: registers
    assert "fresh-tpl" in kv and kv.length_of("fresh-tpl") == 16
    reused0 = eng.stats.prefix_tokens_reused
    eng.generate(toks, max_new=3, cache_point=cp)  # hit: reuses
    assert eng.stats.prefix_tokens_reused - reused0 == 2 * 16


def test_prefix_length_mismatch_falls_back_and_reregisters(prefix_engine):
    """A pooled prefix whose length disagrees with the cache point would
    shift RoPE positions and the attention mask: the engine must treat it
    as a miss, do a full prefill, and re-register the correct prefix."""
    eng, kv = prefix_engine
    rs = np.random.RandomState(2)
    B, Sp, Ss = 2, 16, 6
    tpl = rs.randint(3, 400, (Sp,)).astype(np.int32)
    toks = np.concatenate(
        [np.broadcast_to(tpl, (B, Sp)), rs.randint(3, 400, (B, Ss)).astype(np.int32)],
        axis=1,
    )
    # a stale registration: same template id, WRONG prefix length
    _, cache_full = eng.prefill(toks)
    assert eng.register_prefix("stale-tpl", cache_full, Sp - 4)
    assert kv.length_of("stale-tpl") == Sp - 4
    assert (
        eng.prefill_with_prefix("stale-tpl", toks[:, Sp:], expected_len=Sp)
        is None
    )
    cp = plan_cache_point("stale-tpl", tpl, toks)
    a = eng.generate(toks, max_new=4)
    b = eng.generate(toks, max_new=4, cache_point=cp)  # mismatch -> fallback
    np.testing.assert_array_equal(a, b)
    assert kv.length_of("stale-tpl") == Sp  # re-registered at the cache point


def test_prefix_families_gate():
    """Recurrent-state families can't re-enter a stored prefix: the engine
    must refuse the kv_prefix wiring rather than serve wrong outputs."""
    cfg = registry.get_smoke("rwkv6-3b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    kv = KVPrefixCache(_pool())
    eng = Engine(cfg, params, max_len=48, kv_prefix=kv)
    assert eng.kv_prefix is None

"""Environment + judge semantics."""

import math

import pytest

from repro.envs.base import execute_compute, execute_retrieve, gt_for, judge
from repro.envs.workloads import ALL_ENVS, get_env


@pytest.mark.parametrize("env_name", ALL_ENVS)
def test_generation_valid(env_name):
    env = get_env(env_name)
    tasks = env.generate(30, seed=1)
    assert len(tasks) == 30
    for t in tasks:
        assert math.isfinite(t.gt_answer)
        assert t.intent.keyword
        assert "{" not in t.query  # all slots filled
        # every required field exists in the context
        for f in t.intent.all_fields:
            assert f in t.context, (env_name, t.intent.id, f)
        # gt recomputes
        assert gt_for(t.intent, t.context) == t.gt_answer


def test_interpreter_retrieve_and_compute():
    ctx = {"a_field": 10.0, "b_field": 4.0}
    vals = execute_retrieve({"retrieve": ["a_field", "b_field", "missing"]}, ctx)
    assert vals == {"a_field": 10.0, "b_field": 4.0}
    assert execute_compute("a / b", {"a": 10.0, "b": 4.0}) == 2.5
    assert execute_compute("__import__('os')", {}) is None  # sandboxed
    assert execute_compute("a +", {"a": 1.0}) is None


def test_judge_rules():
    assert judge(1.01, 1.01)
    assert judge(1.0152, 1.01)  # <2% slack... actually 0.5%
    assert judge(101.0, 1.01)  # percent form
    assert not judge(2.0, 1.01)
    assert not judge(None, 1.0)
    assert not judge(float("nan"), 1.0)
    assert judge(0.0, 0.0)


def test_intent_diversity_drives_hit_rates():
    """gaia must have far more distinct intents per task than financebench."""
    gaia = get_env("gaia")
    fin = get_env("financebench")
    g_tasks = gaia.generate(100, seed=0)
    f_tasks = fin.generate(100, seed=0)
    g_uniq = len({t.intent.id for t in g_tasks})
    f_uniq = len({t.intent.id for t in f_tasks})
    assert g_uniq > f_uniq


def test_context_token_ranges():
    fin = get_env("financebench").generate(10, seed=0)
    tab = get_env("tabmwp").generate(10, seed=0)
    assert min(t.context_tokens for t in fin) > max(t.context_tokens for t in tab)

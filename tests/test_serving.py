"""Serving runtime: engine, continuous batching + hedging, two-tier router."""

import threading

import jax
import numpy as np

from repro.configs import registry
from repro.core.cache import PlanCache
from repro.models import lm
from repro.serving.engine import Engine
from repro.serving.router import TierPool, TwoTierRouter
from repro.serving.scheduler import ContinuousBatcher, Request, SchedulerConfig


def test_engine_generate_and_rates(rng_key):
    cfg = registry.get_smoke("olmo-1b")
    params = lm.init_params(cfg, rng_key)
    eng = Engine(cfg, params, max_len=48)
    toks = np.random.RandomState(0).randint(3, 400, (3, 12)).astype(np.int32)
    out = eng.generate(toks, max_new=6)
    assert out.shape == (3, 6)
    r = eng.measured_rates()
    assert r["prefill"] > 0 and r["decode"] > 0


def test_engine_greedy_deterministic(rng_key):
    cfg = registry.get_smoke("qwen2.5-3b")
    params = lm.init_params(cfg, rng_key)
    eng = Engine(cfg, params, max_len=48)
    toks = np.random.RandomState(1).randint(3, 400, (2, 10)).astype(np.int32)
    a = eng.generate(toks, max_new=5)
    b = eng.generate(toks, max_new=5)
    np.testing.assert_array_equal(a, b)


def test_engine_pads_after_eos_and_counts_active_rows(rng_key):
    """Rows past their EOS must emit pad_id, not freshly sampled garbage,
    and must stop counting toward decode throughput."""
    cfg = registry.get_smoke("olmo-1b")
    params = lm.init_params(cfg, rng_key)
    eng = Engine(cfg, params, max_len=48)
    toks = np.random.RandomState(0).randint(3, 400, (3, 12)).astype(np.int32)
    # pick the greedy second token of row 0 as eos: row 0 finishes early
    # while other rows (usually) keep generating
    probe = eng.generate(toks, max_new=2)
    eos = int(probe[0, 1])
    d0 = eng.stats.decode_tokens
    out = eng.generate(toks, max_new=6, eos_id=eos, pad_id=1)
    for r in range(out.shape[0]):
        hits = np.where(out[r] == eos)[0]
        if hits.size:
            assert (out[r, hits[0] + 1 :] == 1).all(), f"row {r} post-EOS garbage"
    # decode_tokens counts only rows still generating: strictly fewer than
    # B * steps once any row finished before the last emitted step
    steps = out.shape[1] - 1
    finished_early = any(
        np.where(out[r] == eos)[0].size and np.where(out[r] == eos)[0][0] < steps
        for r in range(out.shape[0])
    )
    if finished_early:
        assert eng.stats.decode_tokens - d0 < out.shape[0] * steps


def test_engine_generate_rejects_over_capacity(rng_key):
    """prompt + max_new beyond max_len must fail loudly up front — the old
    ``max_len + 8`` slack let decode scribble past the cache end."""
    import pytest

    cfg = registry.get_smoke("olmo-1b")
    params = lm.init_params(cfg, rng_key)
    eng = Engine(cfg, params, max_len=32)
    toks = np.random.RandomState(0).randint(3, 400, (2, 12)).astype(np.int32)
    with pytest.raises(ValueError, match="max_len"):
        eng.generate(toks, max_new=21)
    out = eng.generate(toks, max_new=20)  # exact capacity is fine
    assert out.shape == (2, 20)


def test_engine_prefill_counts_only_valid_tokens(rng_key):
    """prefill_tokens must reflect real tokens, not the padded (B, S)
    rectangle, or measured_rates() overstates prefill throughput."""
    cfg = registry.get_smoke("olmo-1b")
    params = lm.init_params(cfg, rng_key)
    eng = Engine(cfg, params, max_len=48)
    toks = np.random.RandomState(0).randint(3, 400, (2, 16)).astype(np.int32)
    toks[0, 10:] = 0  # right-padded row: 10 valid
    lengths = np.asarray([10, 16])
    eng.prefill(toks, n_valid=int(lengths.sum()))
    assert eng.stats.prefill_tokens == 26
    eng.generate(toks, max_new=4, prompt_lengths=lengths)
    assert eng.stats.prefill_tokens == 52


# -- continuous batching -------------------------------------------------------


def test_continuous_batching_completes_all():
    clock = {"t": 0.0}

    def fake_clock():
        clock["t"] += 0.01
        return clock["t"]

    sched = ContinuousBatcher(SchedulerConfig(max_batch=4, hedge_after_s=1e9),
                              clock=fake_clock)
    for i in range(20):
        sched.submit(Request(arrival=fake_clock(), id=f"r{i}", max_new=5))
    stats = sched.run_until_idle()
    assert stats["completed"] == 20
    assert stats["hedges"] == 0
    # slot reuse: 20 reqs x 5 steps / 4 slots = 25 min steps
    assert stats["steps"] >= 25


def test_straggler_hedging_triggers():
    clock = {"t": 0.0}

    def fake_clock():
        clock["t"] += 0.5  # slow steps -> deadline exceeded
        return clock["t"]

    sched = ContinuousBatcher(
        SchedulerConfig(max_batch=2, hedge_after_s=2.0, n_replicas=2),
        clock=fake_clock,
    )
    for i in range(4):
        sched.submit(Request(arrival=0.0, id=f"r{i}", max_new=30))
    stats = sched.run_until_idle()
    assert stats["completed"] == 4
    assert stats["hedges"] > 0
    assert stats["wasted_steps"] > 0  # hedging costs duplicated work


# -- tier pools ----------------------------------------------------------------


def test_tier_pool_round_robin_visits_every_replica():
    pool = TierPool("small", replicas=["r0", "r1", "r2"])
    # starts at replica 0 and cycles through all of them (the old
    # increment-before-index rotation never served slot 0)
    assert [pool.pick() for _ in range(6)] == ["r0", "r1", "r2", "r0", "r1", "r2"]


def test_tier_pool_hedged_dispatch_reuses_one_executor():
    pool = TierPool("large", replicas=["a", "b"])
    assert pool.dispatch(lambda e: e, hedge=True) in ("a", "b")
    ex = pool._executor
    assert ex is not None
    assert pool.dispatch(lambda e: e, hedge=True) in ("a", "b")
    assert pool._executor is ex  # one pool per TierPool, not per call
    pool.close()
    assert pool._executor is None


def test_tier_pool_hedged_failover_serves_surviving_replica():
    """A replica that times out must not surface: the hedge's success wins.
    With the failover guard ablated (the repro.sim seam) the single
    dispatch propagates the timeout."""
    def flaky(eng):
        if eng == "bad":
            raise TimeoutError("engine timed out")
        return f"served-by-{eng}"

    pool = TierPool("large", replicas=["bad", "good"])
    for _ in range(4):  # every rotation parity: failover always saves it
        assert pool.dispatch(flaky, hedge=True) == "served-by-good"
    pool.close()

    ablated = TierPool("large", replicas=["bad", "good"], hedge_failover=False)
    import pytest
    with pytest.raises(TimeoutError):
        ablated.dispatch(flaky, hedge=True)  # picks replica 0 ("bad")
    ablated.close()


def test_tier_pool_hedged_raises_only_when_all_replicas_fail():
    def always_bad(eng):
        raise RuntimeError(f"{eng} down")

    pool = TierPool("large", replicas=["a", "b"])
    import pytest
    with pytest.raises(RuntimeError):
        pool.dispatch(always_bad, hedge=True)
    pool.close()


def test_tier_pool_unhedged_skips_executor():
    pool = TierPool("actor", replicas=["only"])
    assert pool.dispatch(lambda e: e, hedge=True) == "only"  # <2 replicas
    assert pool._executor is None
    pool.close()


# -- two-tier router ------------------------------------------------------------


def test_router_routes_by_cache_and_async_cachegen():
    cache = PlanCache(capacity=10)
    calls = {"large": 0, "small": 0}

    router = TwoTierRouter(
        cache,
        extract_keyword=lambda req: req["kw"],
        plan_large=lambda req: calls.__setitem__("large", calls["large"] + 1)
        or {"plan": "fresh"},
        plan_small_with_template=lambda req, tpl: calls.__setitem__(
            "small", calls["small"] + 1
        )
        or {"plan": "adapted", "tpl": tpl},
        make_template=lambda req, res: {"tpl_for": req["kw"]},
        async_cachegen=True,
    )
    r1 = router.route({"kw": "mean calculation"})
    assert r1["plan"] == "fresh" and calls["large"] == 1
    router.drain()  # async insert lands
    r2 = router.route({"kw": "mean calculation"})
    assert r2["plan"] == "adapted" and calls["small"] == 1
    m = router.metrics.snapshot()
    assert m["hit_rate"] == 0.5 and m["async_cachegens"] == 1
    router.close()


def test_router_route_batch_single_lookup_pass():
    """route_batch answers the whole batch via one lookup_batch pass; fuzzy
    near-keywords resolve against the cache's incremental index."""
    cache = PlanCache(capacity=10, fuzzy=True, fuzzy_threshold=0.7)
    cache.insert("working capital ratio", {"tpl_for": "working capital ratio"})

    router = TwoTierRouter(
        cache,
        extract_keyword=lambda req: req["kw"],
        plan_large=lambda req: {"plan": "fresh"},
        plan_small_with_template=lambda req, tpl: {"plan": "adapted", "tpl": tpl},
        make_template=lambda req, res: {"tpl_for": req["kw"]},
        async_cachegen=False,
    )
    out = router.route_batch(
        [
            {"kw": "working capital ratio"},           # exact hit
            {"kw": "working capital ratio analysis"},  # fuzzy hit
            {"kw": "quantum chromodynamics"},          # miss -> large tier
        ]
    )
    assert [o["plan"] for o in out] == ["adapted", "adapted", "fresh"]
    m = router.metrics.snapshot()
    assert m["requests"] == 3
    assert m["small_tier_calls"] == 2 and m["large_tier_calls"] == 1
    # the miss distilled its template into the cache synchronously
    assert router.route({"kw": "quantum chromodynamics"})["plan"] == "adapted"
    router.close()


def test_router_async_does_not_block():
    # event-gated instead of sleep-timed: route() must RETURN while the
    # cache generation is still provably blocked on the event (no
    # wall-clock margins, so no flakiness on a loaded CI box)
    cache = PlanCache(capacity=10)
    release = threading.Event()
    slow = {"done": False}

    def make_template(req, res):
        assert release.wait(timeout=30)
        slow["done"] = True
        return {"t": 1}

    router = TwoTierRouter(
        cache,
        extract_keyword=lambda r: "k",
        plan_large=lambda r: "res",
        plan_small_with_template=lambda r, t: "hit",
        make_template=make_template,
        async_cachegen=True,
    )
    assert router.route({}) == "res"
    assert not slow["done"]  # response returned; cachegen still gated
    release.set()
    router.close()  # drains the pending cachegen
    assert slow["done"]

"""repro.memory: PlanStore conformance suite, eviction policies, match
pipeline, and the method registry round-trip."""

import pytest

from repro.core.cache import PlanCache
from repro.core.distributed_cache import DistributedPlanCache
from repro.core.harness import METHODS, run_workload
from repro.memory import (
    AgentMethod,
    CostAwarePolicy,
    LRUPolicy,
    METHOD_REGISTRY,
    PlanStore,
    build_pipeline,
    make_method,
    make_policy,
    method_names,
    register_method,
)


# -- PlanStore conformance ----------------------------------------------------
#
# One behavioral contract, every implementation x policy x index backend.

STORE_KINDS = ["plan", "distributed"]
POLICIES = ["lru", "lfu", "cost"]
BACKENDS = [None, "brute", "bucketed"]  # None = exact-only pipeline


def make_store(kind: str, policy: str, backend):
    kw = dict(eviction=policy)
    if backend is not None:
        kw.update(fuzzy=True, fuzzy_threshold=0.7, index_backend=backend)
    if kind == "plan":
        return PlanCache(capacity=64, **kw)
    return DistributedPlanCache(3, replication=2, capacity_per_node=64, **kw)


@pytest.mark.parametrize("kind", STORE_KINDS)
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_plan_store_conformance(kind, policy, backend):
    s = make_store(kind, policy, backend)
    assert isinstance(s, PlanStore)  # protocol, not hasattr probing

    # singular ops are the batch primitives with a batch of one
    s.insert("working capital ratio", 1)
    assert s.lookup("working capital ratio") == 1
    assert "working capital ratio" in s and len(s) == 1

    # batch ops: one wave in, one wave out, order preserved
    s.insert_batch([(f"key number {i}", i) for i in range(8)])
    got = s.lookup_batch([f"key number {i}" for i in range(8)] + ["absent"])
    assert got[:8] == list(range(8)) and got[8] is None
    assert sorted(s.keys()) == sorted(
        ["working capital ratio"] + [f"key number {i}" for i in range(8)]
    )

    if backend is not None:  # fuzzy stage resolves near-keywords
        assert s.lookup("working capital ratio analysis") == 1

    # stats account every probe
    assert s.stats.inserts == 9
    assert s.stats.hits >= 9 and s.stats.misses >= 1

    # remove is exact and idempotent
    assert s.remove("key number 0") is True
    assert "key number 0" not in s
    if backend is None:
        assert s.lookup("key number 0") is None
    else:  # a fuzzy store legitimately resolves the gap to a near key
        assert s.lookup("key number 0") in (None, *range(1, 8))
    assert s.remove("key number 0") is False

    s.clear()
    assert len(s) == 0 and s.keys() == [] and "key number 1" not in s


@pytest.mark.parametrize("policy", POLICIES)
def test_capacity_bound_under_every_policy(policy):
    c = PlanCache(capacity=4, eviction=policy)
    for i in range(10):
        c.insert(f"k{i}", i)
    assert len(c) == 4 and c.stats.evictions == 6
    # with no accesses every policy degenerates to insertion order
    assert sorted(c.keys()) == [f"k{i}" for i in range(6, 10)]


@pytest.mark.parametrize("policy", POLICIES)
def test_ttl_composes_with_any_policy(policy):
    from repro.sim.clock import VirtualClock

    clock = VirtualClock()
    c = PlanCache(capacity=8, eviction=policy, ttl_s=5.0, clock=clock)
    c.insert("k", 1)
    assert c.lookup("k") == 1
    clock.advance(5.1)
    assert c.lookup("k") is None  # stale once the TTL passes, any policy


# -- tiered memory: cold tier under every store x policy x backend ------------


def make_cold_store(kind: str, policy: str, backend, cold_dir: str):
    kw = dict(eviction=policy)
    if backend is not None:
        kw.update(fuzzy=True, fuzzy_threshold=0.7, index_backend=backend)
    if kind == "plan":
        return PlanCache(capacity=4, cold_dir=cold_dir, **kw)
    return DistributedPlanCache(
        2, replication=1, capacity_per_node=4, cold_dir=cold_dir, **kw
    )


@pytest.mark.parametrize("kind", STORE_KINDS)
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_cold_tier_conformance(kind, policy, backend, tmp_path):
    """With a cold tier, capacity eviction loses NOTHING: every inserted
    (never-removed) key stays resolvable — hot, or promoted on demand."""
    s = make_cold_store(kind, policy, backend, str(tmp_path / "cold"))
    keys = [f"key number {i}" for i in range(12)]
    s.insert_batch([(k, i) for i, k in enumerate(keys)])

    got = s.lookup_batch(keys)
    if policy == "lru":
        # LRU promotes never self-evict (the promoted key is newest), so
        # every key answers; lfu/cost promotes into a fully-reused hot set
        # may pick THEMSELVES as cascade victim and re-spill — that wave
        # misses, but the entry is still cold, not lost
        assert all(v is not None for v in got)
    if backend is None and policy == "lru":
        assert got == list(range(12))  # exact pipeline: own value each

    # nothing is ever lost: every key is still hot or cold somewhere
    shards = [s] if kind == "plan" else list(s.shards.values())
    for k in keys:
        assert any(k in sh or k in sh.cold for sh in shards)

    # hot tier stays capacity-bounded; the overflow lives cold
    assert len(s) <= 4 * len(shards)

    spilled = sum(sh.stats.spills for sh in shards)
    assert spilled > 0
    if backend is None:
        # exact-only misses reach the cold stage (a fuzzy pipeline may
        # legitimately resolve them to a near key first)
        assert sum(sh.stats.promotes for sh in shards) > 0

    # remove reaches the cold tier: nothing resurrects on a later miss
    assert s.remove(keys[0]) is True
    if backend is None:
        assert s.lookup(keys[0]) is None
    assert s.remove(keys[0]) is False

    # clear wipes BOTH tiers
    s.clear()
    assert len(s) == 0
    if backend is None:
        assert s.lookup_batch(keys) == [None] * 12


def _make_template(n_outputs=4, body="x" * 300):
    from repro.core.template import PlanStep, PlanTemplate

    steps = [PlanStep("message", f"round {i}: {body}",
                      {"tool": "search", "arg": f"slot-{i}"})
             for i in range(2)]
    steps += [PlanStep("output", f"observation {i}: {body}", None)
              for i in range(n_outputs)]
    steps += [PlanStep("answer", f"final: {body}", None)]
    return PlanTemplate("sample keyword", steps, source_task="task " + body)


def test_spill_promote_preserves_template_semantics(tmp_path):
    """Round-trip through the on-disk segment encoding is exact when the
    compaction budget is not binding — steps, ops, and metadata survive."""
    tpl = _make_template()
    c = PlanCache(capacity=1, cold_dir=str(tmp_path / "cold"),
                  cold_budget_tokens=10**6)
    c.insert("tpl key", tpl, context="the source query")
    c.insert("filler key", 0)  # evicts + spills the template
    assert "tpl key" not in c and "tpl key" in c.cold

    back = c.lookup("tpl key")  # promote
    assert back is not tpl  # a round-trip, not the same object
    assert [s.to_json() for s in back.steps] == [s.to_json() for s in tpl.steps]
    assert (back.keyword, back.source_task, back.uses) == (
        tpl.keyword, tpl.source_task, tpl.uses)
    # the insertion context came back through the promote path too
    assert c._store["tpl key"].context == "the source query"


def test_compaction_idempotent_and_never_grows():
    from repro.memory import compact_template

    tpl = _make_template()
    once, saved = compact_template(tpl, budget_tokens=60)
    assert saved > 0 and once.size_tokens() < tpl.size_tokens()
    assert once.size_tokens() == tpl.size_tokens() - saved
    # skeleton preserved: message ops and the answer survive compaction
    assert [s.op for s in once.message_steps()] == \
        [s.op for s in tpl.message_steps()]
    assert once.answer_step() is not None
    # idempotent: a second pass is the identity
    twice, saved2 = compact_template(once, budget_tokens=60)
    assert saved2 == 0
    assert [s.to_json() for s in twice.steps] == [s.to_json() for s in once.steps]
    # non-templates pass through untouched
    assert compact_template({"k": 1}, budget_tokens=1) == ({"k": 1}, 0)


def test_conditional_admission_insert_if_newer():
    """A stale background wave (token captured before a newer client
    insert) must not clobber the newer entry; a fresh wave still lands."""
    from repro.sim.clock import VirtualClock

    clock = VirtualClock()
    c = PlanCache(capacity=8, clock=clock)
    token = c.now()
    clock.advance(1.0)
    c.insert("kw", "client-v2")  # newer write after the token was read
    c.insert("kw", "stale-distilled", unless_written_since=token)
    assert c.lookup("kw") == "client-v2"
    assert c.stats.stale_insert_skips == 1
    # a token newer than the entry admits the write
    clock.advance(1.0)
    c.insert("kw", "fresh-distilled", unless_written_since=c.now())
    assert c.lookup("kw") == "fresh-distilled"
    # absent key: the conditional insert lands unconditionally
    c.insert("new kw", "v0", unless_written_since=c.now())
    assert c.lookup("new kw") == "v0"


# -- policy behavior ----------------------------------------------------------


def test_lfu_keeps_frequent_entry():
    c = PlanCache(capacity=3, eviction="lfu")
    c.insert("hot", 1)
    for _ in range(3):
        c.lookup("hot")
    c.insert("a", 2)
    c.insert("b", 3)
    c.insert("c", 4)  # evicts one of the cold entries, never "hot"
    assert "hot" in c and len(c) == 3


def test_cost_aware_keeps_high_value_template():
    class Tpl:
        def __init__(self, uses, tokens):
            self.uses = uses
            self._tokens = tokens

        def size_tokens(self):
            return self._tokens

    c = PlanCache(capacity=2, eviction="cost")
    c.insert("big", Tpl(uses=5, tokens=400))  # oldest but most valuable
    c.insert("small-1", Tpl(uses=0, tokens=10))
    c.insert("small-2", Tpl(uses=0, tokens=10))
    assert "big" in c and "small-1" not in c  # LRU would have evicted "big"


def test_policy_instance_and_unknown_name():
    c = PlanCache(capacity=2, eviction=LRUPolicy())
    c.insert("a", 1)
    assert c.lookup("a") == 1
    with pytest.raises(ValueError):
        make_policy("nope")
    with pytest.raises(ValueError):
        make_policy("ttl")  # ttl requires ttl_s
    # ttl_s wraps any base policy in TTL expiry
    wrapped = make_policy("cost", ttl_s=5.0)
    assert isinstance(wrapped.inner, CostAwarePolicy)


def test_distributed_rejects_policy_instance():
    with pytest.raises(TypeError):
        DistributedPlanCache(2, eviction=LRUPolicy())


# -- match pipeline -----------------------------------------------------------


def test_semantic_stage_matches_on_insert_context():
    c = PlanCache(
        capacity=8, pipeline=("exact", "semantic"), semantic_threshold=0.5
    )
    c.insert(
        "kw-1", "tpl",
        context="What is the FY2019 working capital ratio for Costco?",
    )
    # different keyword, paraphrased query -> semantic stage resolves it
    assert (
        c.lookup(
            "kw-2",
            context="What is the FY2021 working capital ratio for Best Buy?",
        )
        == "tpl"
    )
    assert c.lookup("kw-3", context="orbital mechanics of jupiter") is None


def test_semantic_stage_falls_back_to_key_text():
    # query-keyed store (the semantic baseline's shape): context defaults
    # to the key at insert AND lookup
    c = PlanCache(capacity=8, pipeline=("exact", "semantic"),
                  semantic_threshold=0.6)
    c.insert("what is the net profit margin for Acme", "answer")
    assert c.lookup("what is the net profit margin for Acme Corp") == "answer"


def test_full_cascade_pipeline_order():
    c = PlanCache(
        capacity=8,
        pipeline=("exact", "fuzzy", "semantic"),
        fuzzy_threshold=0.7,
        semantic_threshold=0.5,
    )
    c.insert("working capital ratio", "wc",
             context="What is FY2019 working capital ratio for Costco?")
    assert c.lookup("working capital ratio") == "wc"  # exact
    assert c.lookup("working capital ratio analysis") == "wc"  # fuzzy
    assert (  # neither keyword matches; the query context does
        c.lookup("liquidity check",
                 context="What is FY2020 working capital ratio for Target?")
        == "wc"
    )


def test_caller_key_vectors_do_not_poison_semantic_stage():
    # the vectors= channel ships KEY embeddings (for fuzzy stages); the
    # semantic stage must still embed the context text itself, or
    # paraphrase matching silently dies on cascade stores
    from repro.index import embed

    c = PlanCache(
        capacity=8,
        pipeline=("exact", "fuzzy", "semantic"),
        fuzzy_threshold=0.7,
        semantic_threshold=0.5,
    )
    kw = "working capital ratio"
    c.insert(kw, "tpl",
             context="What is FY2019 working capital ratio for Costco?",
             vector=embed(kw))
    assert (  # semantic stage matches the context, not the shipped vector
        c.lookup("liquidity check",
                 context="What is FY2020 working capital ratio for Target?")
        == "tpl"
    )


def test_build_pipeline_rejects_unknown_stage():
    with pytest.raises(ValueError):
        build_pipeline(("exact", "psychic"))


def test_distributed_store_accepts_contexts():
    # contexts ride through the tiered fan-out to each shard's pipeline
    # (exact shards ignore them; the call shape is part of the protocol)
    dc = DistributedPlanCache(3, replication=1, capacity_per_node=16)
    dc.insert("kw", 7, context="some query text")
    assert dc.lookup_batch(["kw"], contexts=["other text"]) == [7]
    assert dc.lookup("kw", context="third text") == 7


# -- replication ships (key, vector) pairs ------------------------------------


def test_replicated_insert_embeds_each_key_exactly_once(monkeypatch):
    import repro.core.distributed_cache as dcm
    import repro.index as rindex
    import repro.index.bank as bank

    embedded_texts = []
    real_embed_batch = bank.embed_batch

    def counting_batch(texts):
        embedded_texts.extend(texts)
        return real_embed_batch(texts)

    # patch every module-level binding on the insert-side embed path
    # (bank.embed funnels through bank.embed_batch, so this covers the
    # per-key path too)
    for mod in (bank, rindex, dcm):
        monkeypatch.setattr(mod, "embed_batch", counting_batch)

    dc = DistributedPlanCache(4, replication=3, capacity_per_node=64,
                              fuzzy=True)
    dc.insert_batch([(f"keyword number {i}", i) for i in range(10)])
    dc.insert("solo keyword", 99)
    # 10 wave keys + 1 single key, each embedded ONCE despite 3 replicas
    assert sorted(embedded_texts) == sorted(
        [f"keyword number {i}" for i in range(10)] + ["solo keyword"]
    )
    # and the replicas really did index the shipped vectors
    assert dc.lookup("solo keyword") == 99
    assert dc.lookup_batch(["keyword number 3"]) == [3]


# -- method registry ----------------------------------------------------------


def test_methods_enumerates_registry_and_includes_cascade():
    assert METHODS == method_names()
    for m in ("accuracy_optimal", "cost_optimal", "semantic",
              "full_history", "apc", "cascade"):
        assert m in METHODS


@pytest.mark.parametrize("method", list(METHOD_REGISTRY))
def test_every_registered_method_runs_through_the_harness(method):
    r = run_workload("financebench", method, 12)
    assert r.method == method
    assert 0.0 <= r.accuracy <= 1.0
    assert r.cost > 0
    assert len(r.records) == 0  # keep_records defaults off


def test_unknown_method_raises_value_error():
    with pytest.raises(ValueError):
        run_workload("financebench", "not-a-method", 4)


def test_register_method_roundtrip():
    @register_method("_test_stub")
    class Stub(AgentMethod):
        def run(self, task):
            return "ran"

    try:
        assert METHOD_REGISTRY["_test_stub"] is Stub
        assert Stub.name == "_test_stub"
        m = make_method("_test_stub", agent=object())
        assert m.run(None) == "ran"
    finally:
        METHOD_REGISTRY.pop("_test_stub", None)


def test_cascade_is_cheaper_than_accuracy_optimal():
    cascade = run_workload("financebench", "cascade", 60)
    ao = run_workload("financebench", "accuracy_optimal", 60)
    apc = run_workload("financebench", "apc", 60)
    assert cascade.cost < ao.cost
    assert cascade.accuracy > 0.8 * ao.accuracy
    # the semantic tail stage can only ADD hits over plain apc
    assert cascade.hit_rate >= apc.hit_rate

"""Per-arch smoke + prefill/decode equivalence (the core serving invariant)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm

ARCHS = registry.ARCH_NAMES


def _fp32(cfg):
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    return cfg


def _batches(cfg, key, B, S):
    tok = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    if cfg.family == "audio":
        fr = jax.random.normal(key, (B, cfg.encoder.num_frames, cfg.d_model))
        return {"frames": fr, "tokens": tok}, tok
    return {"tokens": tok}, tok


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng_key):
    cfg = registry.get_smoke(arch)
    p = lm.init_params(cfg, rng_key)
    B, S = 2, 10
    batch, tok = _batches(cfg, jax.random.PRNGKey(1), B, S)
    full = dict(batch)
    full["tokens"] = tok
    logits, aux, _ = lm.forward(cfg, p, full)
    assert logits.shape == (B, S + 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_equivalence(arch, rng_key):
    """forward(S+1)[-1] == prefill(S) + decode_step(token S)."""
    cfg = _fp32(registry.get_smoke(arch))
    p = lm.init_params(cfg, rng_key)
    B, S = 2, 34  # multi-chunk for ssm (smoke chunk=16)
    batch, tok = _batches(cfg, jax.random.PRNGKey(2), B, S)
    full = dict(batch)
    full["tokens"] = tok
    logits_full, _, _ = lm.forward(cfg, p, full)
    pre = dict(batch)
    pre["tokens"] = tok[:, :S]
    _, cache = lm.prefill(cfg, p, pre, cache_len=S + 4)
    ld, cache2 = lm.decode_step(cfg, p, cache, tok[:, S : S + 1])
    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(ld[:, 0], np.float32)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
    assert rel < 5e-4, (arch, rel)
    assert int(cache2["length"]) == S + 1


@pytest.mark.parametrize("arch", ["qwen3-4b", "rwkv6-3b", "zamba2-2.7b", "whisper-tiny"])
def test_multi_step_decode_matches_forward(arch, rng_key):
    cfg = _fp32(registry.get_smoke(arch))
    p = lm.init_params(cfg, rng_key)
    B, S, extra = 1, 18, 3
    batch, tok = _batches(cfg, jax.random.PRNGKey(3), B, S + extra - 1)
    full = dict(batch)
    full["tokens"] = tok
    logits_full, _, _ = lm.forward(cfg, p, full)
    pre = dict(batch)
    pre["tokens"] = tok[:, :S]
    _, cache = lm.prefill(cfg, p, pre, cache_len=S + extra + 2)
    for i in range(extra):
        ld, cache = lm.decode_step(cfg, p, cache, tok[:, S + i : S + i + 1])
        a = np.asarray(logits_full[:, S + i], np.float32)
        b = np.asarray(ld[:, 0], np.float32)
        rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
        assert rel < 1e-3, (arch, i, rel)


def test_vlm_embeds_path(rng_key):
    cfg = registry.get_smoke("qwen2-vl-7b")
    p = lm.init_params(cfg, rng_key)
    B, S = 2, 8
    emb = jax.random.normal(rng_key, (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
    logits, _, _ = lm.forward(cfg, p, {"embeds": emb, "positions": pos})
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_mrope_positions_change_output(rng_key):
    """M-RoPE must actually use the 3D position streams."""
    cfg = registry.get_smoke("qwen2-vl-7b")
    p = lm.init_params(cfg, rng_key)
    B, S = 1, 8
    emb = jax.random.normal(rng_key, (B, S, cfg.d_model))
    pos1 = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
    pos2 = pos1.at[1].set(pos1[1] * 3)  # different height stream
    l1, _, _ = lm.forward(cfg, p, {"embeds": emb, "positions": pos1})
    l2, _, _ = lm.forward(cfg, p, {"embeds": emb, "positions": pos2})
    assert np.abs(np.asarray(l1 - l2, np.float32)).max() > 1e-4


def test_flash_vs_naive_attention_in_model(rng_key):
    """The chunked flash path (S>512) must match naive attention."""
    from repro.models import attention as attn

    cfg = registry.get_smoke("qwen2.5-3b")
    cfg = dataclasses.replace(cfg, dtype="float32", param_dtype="float32")
    p = attn.attn_init(cfg, rng_key)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 640, cfg.d_model))
    from repro.models.rope import positions_for_rope

    pos = jnp.broadcast_to(jnp.arange(640, dtype=jnp.int32)[None], (2, 640))
    cos, sin = positions_for_rope(cfg, pos, cfg.head_dim)
    o_flash, _ = attn.attention_seq(cfg, p, x, cos, sin, use_flash=True)
    o_naive, _ = attn.attention_seq(cfg, p, x, cos, sin, use_flash=False)
    assert np.abs(np.asarray(o_flash - o_naive, np.float32)).max() < 1e-3
